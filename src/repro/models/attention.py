"""Grouped-query attention with RoPE, qk-norm, bias; train/prefill + decode.

Prefill/train uses a chunked online-softmax ("flash"-style) pure-jnp path so
that 32k-token sequences never materialize (S x S) score tensors — the scan
tiles are what a Pallas splash-attention kernel would stream through VMEM on
real hardware.  Decode is a single-token read over a fixed-size KV cache
(written in place via dynamic_update_slice).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


def init_attention(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, h * hd, dtype, bias=cfg.qkv_bias),
        "wk": layers.dense_init(ks[1], d, kv * hd, dtype, bias=cfg.qkv_bias),
        "wv": layers.dense_init(ks[2], d, kv * hd, dtype, bias=cfg.qkv_bias),
        "wo": layers.dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rms_norm_init(hd, dtype)
        p["k_norm"] = layers.rms_norm_init(hd, dtype)
    return p


def _project_qkv(params, cfg, x, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = layers.dense(params["wq"], x).reshape(B, S, h, hd)
    k = layers.dense(params["wk"], x).reshape(B, S, kv, hd)
    v = layers.dense(params["wv"], x).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = layers.rms_norm(params["k_norm"], k, cfg.norm_eps)
    cos, sin = layers.rope_cos_sin(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    return q, k, v


from repro.models.flash import flash_attention  # noqa: E402  (shared kernel)


def attention_full(params, cfg, x, positions, *, causal: bool = True,
                   kv_override=None) -> jax.Array:
    """Training / prefill attention.  kv_override=(k,v) enables cross-attn."""
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    q, k, v = _project_qkv(params, cfg, x, positions)
    if kv_override is not None:
        k, v = kv_override
        causal = False
    q = q.reshape(B, S, kv, g, hd)
    out = flash_attention(q, k, v, causal=causal)
    out = out.transpose(0, 1, 2, 3, 4).reshape(B, S, h * hd)
    return layers.dense(params["wo"], out)


def attention_full_with_cache(params, cfg, x, positions):
    """Prefill: full attention that also returns the populated KV cache."""
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = flash_attention(q.reshape(B, S, kv, g, hd), k, v, causal=True)
    out = out.reshape(B, S, h * hd)
    return layers.dense(params["wo"], out), {"k": k, "v": v}


def init_cache(cfg, batch: int, max_len: int, dtype, layers_stacked: int = 1):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (layers_stacked, batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(params, cfg, x, cache_k, cache_v, pos):
    """One-token decode step — READ-ONLY on the cache.

    x: (B, 1, d); cache_k/v: (B, S, KV, D); pos: scalar int32 — current
    length — or a (B,) int32 vector of PER-SLOT lengths (continuous
    batching: every serving slot sits at its own cache position, the
    predication idea at the slot level).  Returns (y, k_new, v_new): the
    (B, 1, KV, D) slices for the new token.  The caller commits all
    layers' slices with ONE dynamic_update_slice (wave mode) or per-slot
    scatter (paged mode) on the stacked cache (a per-layer in-scan
    read-modify-write would materialize an unaliased full-cache copy per
    layer on backends without scan buffer donation).
    """
    B, _, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kv
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    S = cache_k.shape[1]
    q = q.reshape(B, 1, kv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s_old = jnp.einsum(
        "bqkgd,bskd->bkgqs", q, cache_k, preferred_element_type=jnp.float32
    ) * scale
    # strictly-older tokens from each slot's own live prefix
    mask = jnp.arange(S)[None, :] < pos_b[:, None]  # (B, S)
    s_old = jnp.where(mask[:, None, None, None, :], s_old, NEG_INF)
    s_new = jnp.einsum(
        "bqkgd,bskd->bkgqs", q, k_new, preferred_element_type=jnp.float32
    ) * scale  # (B,KV,G,1,1): self-attention of the incoming token

    # Two-way online-softmax merge of {cache part, new token} — NOT a
    # concatenate: the cache's seq axis is sharded over `model` at 32k+
    # contexts, and a concat along a sharded axis makes GSPMD all-gather
    # the whole KV cache per layer (measured 0.49 TB/step on
    # qwen3-1.7b@decode_32k).  The merge only reduces over the sharded
    # axis, which lowers to tiny all-reduces of (B,KV,G,1) stats.
    m_old = s_old.max(axis=-1)                      # (B,KV,G,1)
    p_old = jnp.exp(s_old - m_old[..., None])
    l_old = p_old.sum(axis=-1)
    ctx_old = jnp.einsum(
        "bkgqs,bskd->bkgqd", p_old.astype(cache_v.dtype), cache_v,
        preferred_element_type=jnp.float32,
    )  # unnormalized context from the cache
    s_new1 = s_new[..., 0]                          # (B,KV,G,1)
    m = jnp.maximum(m_old, s_new1)
    w_old = jnp.exp(m_old - m)                      # 0 when cache empty
    w_new = jnp.exp(s_new1 - m)
    denom = l_old * w_old + w_new
    v_new5 = v_new.astype(jnp.float32).transpose(0, 2, 1, 3)[:, :, None, :, :]
    out = (ctx_old * w_old[..., None] + v_new5 * w_new[..., None]) / denom[..., None]
    out = out.astype(x.dtype).reshape(B, 1, h * hd)
    return layers.dense(params["wo"], out), k_new, v_new
