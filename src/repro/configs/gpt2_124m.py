"""GPT-2 124M — the paper's own "LLM training / inference (124M)" benchmark.

12L, d_model=768, 12H, vocab=50257, tied embeddings.  d_ff=2048 for the
SwiGLU MLP matches GPT-2's 2x768x3072 MLP parameter count (3x768x2048), so
total params stay ~124M.  LayerNorm as in GPT-2.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gpt2-124m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=2048,
    vocab=50257,
    tie_embeddings=True,
    rms_norm=False,
)

SMOKE = ModelConfig(
    name="gpt2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
    rms_norm=False,
    param_dtype="float32",
    compute_dtype="float32",
)
