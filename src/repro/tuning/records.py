"""Persisted tuning records: the content-addressed best-known configs.

A :class:`TuningRecord` captures the outcome of one :func:`~repro.tuning.
tune.tune` run — the winning config, the measured best/default times, the
roofline-predicted times, and how much of the space was pruned analytically
versus timed.  Records persist through the same content-addressed
:class:`~repro.analysis.store.ArtifactStore` machinery the analysis
pipeline uses for compiled-artifact events (atomic writes, corrupt-entry
recovery), in a ``tuning/`` subdirectory of the artifact cache — so the
zero-recompile story of the event store extends to a zero-re-tune story: a
second process asking to tune an already-tuned (kernel, chip, dtype) gets a
store hit and performs **zero timing runs**.

The fingerprint is the tuning analogue of
:func:`~repro.analysis.store.workload_fingerprint`:

    kernel fn bytecode hash + example-arg shapes/dtypes + chip + dtype +
    the declarative space content + versions

so changing the kernel body, the problem shape, the chip model, the ELEN,
or the search space re-tunes; nothing else does.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Any, Dict, Optional, Tuple

from repro.analysis.store import (
    ArtifactStore,
    _default_dir,
    _store_for,
    arg_signature,
    fn_token,
)
from repro.tuning.space import TuningSpace

TUNING_VERSION = 1


@dataclasses.dataclass
class TuningRecord:
    """Best-known config for one (kernel, chip, dtype) on one problem."""

    kernel: str
    chip: str
    dtype: str
    fingerprint: str
    config: Dict[str, Any]
    default_config: Dict[str, Any]
    best_time_s: float
    default_time_s: float
    predicted_best_s: float = 0.0
    predicted_default_s: float = 0.0
    space_size: int = 0  # raw cartesian size of the searched space
    candidates: int = 0  # valid configs after clamp/dedup/VMEM
    pruned: int = 0  # dropped by the roofline score before timing
    timed: int = 0  # configs actually timed by the original run
    mode: str = "interpret"
    problem: str = ""
    # runtime-only: True when this record came from the store (not persisted)
    cached: bool = dataclasses.field(default=False, compare=False)

    @property
    def speedup_vs_default(self) -> float:
        """Measured best-vs-default speedup (1.0 when default won)."""
        if self.best_time_s <= 0:
            return 1.0
        return max(self.default_time_s / self.best_time_s, 1.0)

    @property
    def predicted_speedup(self) -> float:
        """Roofline-predicted tuned-vs-default speedup."""
        if self.predicted_best_s <= 0:
            return 1.0
        return max(self.predicted_default_s / self.predicted_best_s, 1.0)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.pop("cached")
        d["speedup_vs_default"] = self.speedup_vs_default
        d["predicted_speedup"] = self.predicted_speedup
        d["cached"] = self.cached  # reported, but not trusted on load
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TuningRecord":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields and k != "cached"})

    def row(self) -> Dict[str, Any]:
        """Flat projection for tables / tuning.json summaries."""
        return {
            "kernel": self.kernel,
            "chip": self.chip,
            "dtype": self.dtype,
            "config": " ".join(f"{k}={v}" for k, v in sorted(self.config.items())),
            "best_ms": f"{self.best_time_s * 1e3:.3f}",
            "default_ms": f"{self.default_time_s * 1e3:.3f}",
            "speedup": f"{self.speedup_vs_default:.3g}x",
            "pred": f"{self.predicted_speedup:.3g}x",
            "timed": self.timed,
            "pruned": self.pruned,
            "cached": self.cached,
        }


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------


def tuning_fingerprint(
    kernel: str,
    fn: Any,
    args: Tuple,
    chip: str,
    dtype: str,
    space: TuningSpace,
) -> str:
    """Content address of one tuning decision (see module docstring)."""
    h = hashlib.sha256()
    h.update(f"tuning-v{TUNING_VERSION}|{kernel}|{chip}|{dtype}|".encode())
    h.update(space.token().encode())
    h.update(b"|")
    for a in args:
        h.update(arg_signature(a).encode())
        h.update(b";")
    h.update(fn_token(fn).encode())
    return h.hexdigest()[:32]


# ---------------------------------------------------------------------------
# The store (an ArtifactStore over the tuning/ subdirectory)
# ---------------------------------------------------------------------------


def default_tuning_dir() -> str:
    """``<artifact dir>/tuning`` — rides ``$REPRO_ARTIFACT_DIR`` so test
    isolation and operator overrides cover tuning records for free."""
    return os.path.join(_default_dir(), "tuning")


def default_tuning_store() -> ArtifactStore:
    return _store_for(default_tuning_dir())


def resolve_store(store: Any) -> Optional[ArtifactStore]:
    """None -> no persistence; "default" -> the shared tuning store; any
    other string -> a store rooted at that directory; pass-through else."""
    if store is None or isinstance(store, ArtifactStore):
        return store
    if store == "default":
        return default_tuning_store()
    return _store_for(str(store))


def load_record(store: ArtifactStore, fingerprint: str) -> Optional[TuningRecord]:
    """Record for ``fingerprint``, or None; corrupt payloads are dropped."""
    payload = store.get_json(fingerprint)
    if payload is None:
        return None
    try:
        if payload.get("tuning_version") != TUNING_VERSION:
            raise ValueError(f"tuning version {payload.get('tuning_version')}")
        rec = TuningRecord.from_dict(payload["record"])
    except (ValueError, KeyError, TypeError):
        store.discard(fingerprint)  # reverses the get_json hit
        return None
    rec.cached = True
    return rec


def save_record(store: ArtifactStore, record: TuningRecord) -> str:
    return store.put_json(
        record.fingerprint,
        {
            "workload": record.kernel,
            "kind": "tuning",
            "tuning_version": TUNING_VERSION,
            "record": record.to_dict(),
        },
    )
