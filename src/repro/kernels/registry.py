"""Kernel registry: one jit-wrapper factory for every Pallas kernel.

Each ``kernels/<pkg>/ops.py`` used to hand-roll the same
``functools.partial(jax.jit, static_argnames=(..., "interpret"))`` wrapper.
:func:`register_kernel` replaces those six copies with one factory that
returns a :class:`KernelOps` exposing the three call surfaces:

* ``op(*args)``        — default call (interpret-mode Pallas, CPU-safe);
* ``op.kernel(*args)`` — compiled Pallas path (``interpret=False``);
* ``op.interpret(*args)`` — explicit interpret-mode path;
* ``op.ref(*args)``    — the pure-jnp/numpy oracle.

Registration also auto-registers the kernel as a :class:`~repro.analysis.
workload.Workload` (name ``kernel/<name>``) with a small example problem
and the ref module's analytic flops/bytes model, so every kernel is
reachable through ``repro.analysis.analyze`` with zero extra wiring.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.analysis.workload import Workload, register_lazy


class KernelOps:
    """Call surface for one registered kernel (ref / kernel / interpret)."""

    def __init__(
        self,
        name: str,
        kernel_fn: Callable,
        ref_fn: Optional[Callable] = None,
        *,
        static_argnums: Tuple[int, ...] = (),
        static_argnames: Tuple[str, ...] = (),
    ) -> None:
        self.name = name
        self.raw = kernel_fn
        self._ref = ref_fn
        names = tuple(static_argnames)
        if "interpret" not in names:
            names = names + ("interpret",)
        self._jit = jax.jit(
            kernel_fn, static_argnums=static_argnums or None, static_argnames=names
        )
        functools.update_wrapper(self, kernel_fn, updated=())

    def __call__(self, *args: Any, **kw: Any):
        kw.setdefault("interpret", True)
        return self._jit(*args, **kw)

    def kernel(self, *args: Any, **kw: Any):
        kw["interpret"] = False
        return self._jit(*args, **kw)

    def interpret(self, *args: Any, **kw: Any):
        kw["interpret"] = True
        return self._jit(*args, **kw)

    def lower(self, *args: Any, **kw: Any):
        """AOT-lower the (interpret-mode by default) jitted kernel.

        Exposing ``lower`` lets the analysis pipeline compile a kernel
        workload directly instead of re-wrapping it in ``jax.jit`` — which
        would turn the static arguments into tracers.
        """
        kw.setdefault("interpret", True)
        return self._jit.lower(*args, **kw)

    def ref(self, *args: Any, **kw: Any):
        if self._ref is None:
            raise NotImplementedError(f"kernel {self.name!r} has no ref oracle")
        return self._ref(*args, **kw)

    def __repr__(self) -> str:
        return f"KernelOps({self.name!r})"


KERNELS: Dict[str, KernelOps] = {}

# kernel workload builders, kept so registration can be re-applied after
# repro.analysis.clear_registry() (module import side effects only run once)
_WORKLOAD_BUILDERS: Dict[str, Callable[[], Workload]] = {}


def register_builtin_workloads() -> None:
    """(Re-)register every kernel workload; idempotent discovery hook."""
    for wl_name, builder in _WORKLOAD_BUILDERS.items():
        register_lazy(wl_name, builder, tags=("kernel",), replace=True)


def register_kernel(
    name: str,
    kernel: Optional[Callable] = None,
    *,
    ref: Optional[Callable] = None,
    static_argnums: Tuple[int, ...] = (),
    static_argnames: Tuple[str, ...] = (),
    workload: Optional[Callable[[], Workload]] = None,
):
    """Register a kernel entry point; usable directly or as a decorator.

    ``workload`` is a zero-arg builder returning the kernel's example
    Workload; it is registered lazily as ``kernel/<name>`` so importing the
    registry never constructs example arrays.
    """

    def _do(fn: Callable) -> KernelOps:
        if name in KERNELS:
            raise ValueError(f"kernel {name!r} already registered")
        ops = KernelOps(
            name,
            fn,
            ref,
            static_argnums=static_argnums,
            static_argnames=static_argnames,
        )
        KERNELS[name] = ops
        if workload is not None:
            _WORKLOAD_BUILDERS[f"kernel/{name}"] = workload
            register_lazy(f"kernel/{name}", workload, tags=("kernel",),
                          replace=True)
        return ops

    if kernel is not None:
        return _do(kernel)
    return _do


def get_kernel(name: str) -> KernelOps:
    if name not in KERNELS:
        raise KeyError(f"unknown kernel {name!r}; have {sorted(KERNELS)}")
    return KERNELS[name]


def list_kernels() -> list:
    return sorted(KERNELS)


# ---------------------------------------------------------------------------
# The six kernel packages
# ---------------------------------------------------------------------------

from repro.kernels.flash_decode import kernel as _fd_k, ref as _fd_r  # noqa: E402
from repro.kernels.gemm import kernel as _gemm_k, ref as _gemm_r  # noqa: E402
from repro.kernels.jacobi2d import kernel as _jac_k, ref as _jac_r  # noqa: E402
from repro.kernels.qc_gate import kernel as _qc_k, ref as _qc_r  # noqa: E402
from repro.kernels.spmv import kernel as _spmv_k, ref as _spmv_r  # noqa: E402
from repro.kernels.stream import kernel as _stream_k, ref as _stream_r  # noqa: E402


def _gemm_workload() -> Workload:
    import jax.numpy as jnp

    n = 256
    fb = _gemm_r.flops_bytes(n, n, n, 4)

    def args():
        x = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
        y = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
        return (x, y)

    return Workload(
        name="kernel/gemm", fn=GEMM, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{n}^2", tags=("kernel",),
        notes="MXU-tiled Pallas GEMM; compute-bound Class 4",
    )


def _stream_workload() -> Workload:
    import jax.numpy as jnp

    rows, cols = 2048, 128
    fb = _stream_r.flops_bytes("triad", rows * cols, 4)

    def args():
        a = jnp.ones((rows, cols), jnp.float32)
        b = jnp.ones((rows, cols), jnp.float32)
        return (a, b, 3.0)

    return Workload(
        name="kernel/stream-triad", fn=STREAM_TRIAD, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{rows}x{cols}", tags=("kernel",),
        notes="McCalpin triad; streaming memory-bandwidth-bound Class 2",
    )


def _spmv_workload() -> Workload:
    import numpy as np

    n = 512

    def args():
        vals, cols, nnz = _spmv_r.make_problem(
            jax.random.PRNGKey(0), n, n, row_block=8, max_nnz=64, width_pad=128
        )
        x = jax.random.normal(jax.random.PRNGKey(1), (n,), vals.dtype)
        return (vals, cols, nnz, x)

    # per-nnz accounting (same model as spmv/ops.flops_bytes): 2 FLOPs per
    # nonzero; traffic = val + colidx + gathered x, the x reads being the
    # latency-bound pointer-chasing share
    nnz_np = np.asarray(
        _spmv_r.make_problem(
            jax.random.PRNGKey(0), n, n, row_block=8, max_nnz=64, width_pad=128
        )[2]
    )
    total_nnz = float(nnz_np.sum())
    return Workload(
        name="kernel/spmv", fn=SPMV, args=args, dtype="fp32",
        flops=2.0 * total_nnz, hbm_bytes=total_nnz * (4 + 4 + 4),
        gather_bytes=total_nnz * 4,
        problem=f"{n}^2 zipf", tags=("kernel",),
        notes="predicated block-ELL SpMV; pointer-chasing Class 3",
    )


def _jacobi_workload() -> Workload:
    import jax.numpy as jnp

    n = 256
    fb = _jac_r.flops_bytes(n, n, 4)

    def args():
        return (jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32),)

    return Workload(
        name="kernel/jacobi2d", fn=JACOBI_STEP, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{n}^2", tags=("kernel",),
        notes="5-point stencil sweep; memory-bound Class 2",
    )


def _qc_workload() -> Workload:
    import jax.numpy as jnp

    n_qubits = 14
    fb = _qc_r.flops_bytes(n_qubits, 4)

    def args():
        n_amp = 1 << n_qubits
        re = jnp.zeros((n_amp,), jnp.float32).at[0].set(1.0)
        im = jnp.zeros((n_amp,), jnp.float32)
        return (re, im)

    def one_gate(re, im):
        return RX_GATE(re, im, qubit=0, theta=0.25)

    return Workload(
        name="kernel/qc-gate", fn=one_gate, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"{n_qubits} qubits", tags=("kernel",),
        notes="single RX gate over the state vector; streaming Class 2",
    )


def _flash_decode_workload() -> Workload:
    import jax.numpy as jnp

    B, KV, G, D, S = 2, 2, 4, 16, 64
    valid = (40, 64)
    fb = _fd_r.flops_bytes(B, KV, G, D, valid, dtype_bytes=4)

    def args():
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, KV, G, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        vl = jnp.asarray(valid, jnp.int32)
        return (q, k, v, vl)

    def one_step(q, k, v, vl):
        return FLASH_DECODE(q, k, v, vl, block_s=16)

    return Workload(
        name="kernel/flash-decode", fn=one_step, args=args, dtype="fp32",
        flops=fb["flops"], hbm_bytes=fb["bytes"],
        problem=f"B{B} KV{KV} G{G} D{D} S{S}", tags=("kernel",),
        notes="predicated KV-cache attention decode; GQA reuse lifts AI",
    )


GEMM = register_kernel(
    "gemm", _gemm_k.gemm,
    ref=_gemm_r.gemm_ref,
    static_argnames=("bm", "bn", "bk"),
    workload=_gemm_workload,
)

STREAM_COPY = register_kernel(
    "stream-copy", _stream_k.stream_copy,
    ref=_stream_r.copy_ref,
    static_argnames=("block_rows",),
)
STREAM_SCALE = register_kernel(
    "stream-scale", _stream_k.stream_scale,
    ref=_stream_r.scale_ref,
    static_argnums=(1,), static_argnames=("block_rows",),
)
STREAM_ADD = register_kernel(
    "stream-add", _stream_k.stream_add,
    ref=_stream_r.add_ref,
    static_argnames=("block_rows",),
)
STREAM_TRIAD = register_kernel(
    "stream-triad", _stream_k.stream_triad,
    ref=_stream_r.triad_ref,
    static_argnums=(2,), static_argnames=("block_rows",),
    workload=_stream_workload,
)

SPMV = register_kernel(
    "spmv", _spmv_k.spmv_blockell,
    ref=_spmv_r.spmv_ref,
    static_argnames=("repeat",),
    workload=_spmv_workload,
)
SPMV_FIXED = register_kernel(
    "spmv-fixed-width", _spmv_k.spmv_fixed_width,
    ref=_spmv_r.spmv_ref,
)

JACOBI_STEP = register_kernel(
    "jacobi2d", _jac_k.jacobi_step,
    ref=_jac_r.jacobi_ref,
    static_argnames=("block_rows",),
    workload=_jacobi_workload,
)

RX_GATE = register_kernel(
    "qc-gate", _qc_k.rx_gate,
    static_argnames=("qubit", "theta", "block_outer"),
    workload=_qc_workload,
)

FLASH_DECODE = register_kernel(
    "flash-decode", _fd_k.flash_decode,
    ref=_fd_r.decode_ref,
    static_argnames=("block_s",),
    workload=_flash_decode_workload,
)
