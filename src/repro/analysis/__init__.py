"""Unified Workload API + one-call SVE analysis pipeline (paper Sec. 3).

The paper's end-to-end method — PMU events (Sec. 3.1 / Table 1) -> Eq. 1
metrics (VB, R_ins) -> adapted roofline (Eq. 2) -> Fig. 8 decision tree —
behind two entry points:

* :func:`workload` / :class:`Workload` — describe a unit of work once
  (callable + example args + dtype + optional analytic cost model) and
  register it globally;
* :func:`analyze` / :func:`analyze_sweep` — run the whole pipeline on any
  registered (or ad-hoc) workload in one call, returning a typed
  :class:`SVEAnalysis` report.  Sweeps parallelize with ``jobs=N``
  (single-flight compile dedup), and extracted events persist across
  processes in the content-addressed :class:`ArtifactStore` (fingerprint =
  name + arg shapes/dtypes + fn hash), so repeat runs skip compilation.

    from repro.analysis import analyze, list_workloads

    print(analyze("kernel/gemm").table())
    for name in list_workloads():
        print(analyze(name))

Kernel workloads also surface the roofline-guided autotuner's outlook
(``SVEAnalysis.tuning``); see :mod:`repro.tuning` and ``docs/TUNING.md``.
"""

from repro.analysis.workload import (  # noqa: F401
    Workload,
    clear_registry,
    get_workload,
    list_workloads,
    register,
    register_lazy,
    workload,
)
from repro.analysis.store import (  # noqa: F401
    ArtifactStore,
    default_store,
    workload_fingerprint,
)
from repro.analysis.pipeline import (  # noqa: F401
    ArtifactCache,
    DEFAULT_CACHE,
    DEFAULT_STORE,
    SVEAnalysis,
    analyze,
    analyze_compiled,
    analyze_events,
    analyze_sweep,
    format_table,
)
