"""Scenario-matrix harness contract.

* Matrix expansion is deterministic and seeded: same spec => same cells,
  same seeds, same sampled traffic; the fault axis and the scheduler are
  excluded from seed derivation so a faulted cell's golden twin (and the
  other scheduler's cell) sample byte-identical requests.
* Every fault plan preserves the served token streams exactly: preempted,
  device-lost, and malformed-traffic cells must all match their fault-free
  golden twin uid-for-uid, token-for-token.
* One BenchRun per cell lands in the perf ledger under
  ``scenario/<cell_id>`` and ``python -m repro.perf gate`` gates it.
* SLO violations fail the cell and the gate CLI exits non-zero.
"""

import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.perf.gate import gate_run
from repro.perf.ledger import Ledger, metrics_from_scenario
from repro.scenarios import (
    ArrivalSpec,
    EosSpec,
    MatrixSpec,
    PromptSpec,
    SLOSpec,
    get_plan,
    sample_trace,
    smoke_matrix,
)
from repro.scenarios.runner import (
    format_matrix_markdown,
    record_cell,
    run_cell,
    run_matrix,
)


def _tiny_matrix(**over) -> MatrixSpec:
    """Smallest matrix that still exercises queueing + slot refill."""
    kw = dict(
        arrivals=[ArrivalSpec(kind="poisson", rate=0.5)],
        prompts=[PromptSpec(kind="uniform", lo=4, hi=10)],
        eos=[EosSpec(p_early=0.1)],
        schedulers=["continuous"],
        archs=["gpt2-124m"],
        faults=["none"],
        requests=4,
        max_new=4,
        max_batch=2,
        max_len=32,
        block_size=8,
    )
    kw.update(over)
    return MatrixSpec(**kw)


def _cell(fault="none", **over):
    cells = _tiny_matrix(faults=[fault], **over).cells()
    assert len(cells) == 1
    return cells[0]


# ---------------------------------------------------------------------------
# matrix expansion + seeding
# ---------------------------------------------------------------------------


def test_expansion_is_deterministic():
    a, b = smoke_matrix().cells(), smoke_matrix().cells()
    assert [c.cell_id for c in a] == [c.cell_id for c in b]
    assert [c.seed for c in a] == [c.seed for c in b]
    assert len(a) == len({c.cell_id for c in a}), "cell ids must be unique"


def test_preempt_skipped_under_wave_scheduler():
    ids = [c.cell_id for c in smoke_matrix().cells()]
    assert any("/continuous/" in i and i.endswith("/preempt") for i in ids)
    assert not any("/wave/" in i and i.endswith("/preempt") for i in ids)


def test_twin_and_cross_scheduler_share_traffic_seed():
    spec = _tiny_matrix(schedulers=["continuous", "wave"],
                        faults=["none", "malformed"])
    cells = {c.cell_id: c for c in spec.cells()}
    assert len({c.seed for c in cells.values()}) == 1, (
        "scheduler and fault must be outside the traffic key"
    )
    faulted = next(c for c in cells.values() if c.fault == "malformed")
    twin = faulted.twin()
    assert twin.fault == "none" and twin.seed == faulted.seed


def test_matrix_spec_json_roundtrip(tmp_path):
    spec = smoke_matrix()
    p = tmp_path / "m.json"
    p.write_text(json.dumps(spec.to_dict()))
    back = MatrixSpec.from_json(str(p))
    assert [c.cell_id for c in back.cells()] == [
        c.cell_id for c in spec.cells()]
    assert [c.seed for c in back.cells()] == [c.seed for c in spec.cells()]


# ---------------------------------------------------------------------------
# traffic sampling
# ---------------------------------------------------------------------------


def test_trace_is_reproducible_and_twin_identical():
    cell = _cell("preempt")
    t1, t2 = sample_trace(cell, vocab=256), sample_trace(cell, vocab=256)
    tw = sample_trace(cell.twin(), vocab=256)
    for other in (t2, tw):
        assert len(t1) == len(other)
        for a, b in zip(t1, other):
            assert (a.uid, a.arrive_step, a.max_new_tokens) == (
                b.uid, b.arrive_step, b.max_new_tokens)
            np.testing.assert_array_equal(a.prompt, b.prompt)


def test_trace_well_formed_by_construction():
    cell = _cell(prompts=[PromptSpec(kind="uniform", lo=4, hi=100)])
    for spec in sample_trace(cell, vocab=256):
        assert 1 <= len(spec.prompt) <= cell.max_len - cell.max_new
        assert 1 <= spec.max_new_tokens <= cell.max_new


def test_arrival_processes():
    rng = np.random.default_rng
    from repro.scenarios.traffic import _arrival_steps

    bursty = _arrival_steps(ArrivalSpec(kind="bursty", burst=2, gap=10),
                            6, rng(0))
    assert bursty == [0, 0, 10, 10, 20, 20]
    replay = _arrival_steps(ArrivalSpec(kind="replay", steps=(5, 0, 9)),
                            5, rng(0))
    assert replay == sorted(replay) and replay[0] == 0
    poisson = _arrival_steps(ArrivalSpec(kind="poisson", rate=0.5),
                             8, rng(0))
    assert poisson[0] == 0 and poisson == sorted(poisson)


def test_eos_cap_distribution():
    cell = _cell(eos=[EosSpec(p_early=0.0)])
    assert all(s.max_new_tokens == cell.max_new
               for s in sample_trace(cell, vocab=64))
    ragged = _cell(eos=[EosSpec(p_early=0.6)], requests=8)
    caps = {s.max_new_tokens for s in sample_trace(ragged, vocab=64)}
    assert min(caps) >= 1 and len(caps) > 1, "p_early=0.6 should go ragged"


def test_slo_check_floors_and_ceilings():
    slo = SLOSpec(min_tok_s=1.0, max_p95_latency_s=2.0,
                  max_ttft_p95_s=2.0, min_slot_utilization=0.5)
    ok = {"tok_s": 5.0, "p95_latency_s": 0.1, "ttft_p95_s": 0.1,
          "slot_utilization": 0.9}
    assert slo.check(ok) == []
    bad = dict(ok, tok_s=0.5, p95_latency_s=9.0)
    msgs = slo.check(bad)
    assert len(msgs) == 2 and any("tok/s" in m for m in msgs)
    assert any("missing" in m for m in slo.check({}))


# ---------------------------------------------------------------------------
# fault plans: golden-twin token equivalence
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def preempt_result():
    return run_cell(_cell("preempt"))


def test_preempt_cell_matches_golden_twin(preempt_result):
    r = preempt_result
    assert r.error == ""
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["preemptions"] >= 1, "the fault must actually fire"
    assert r.slo_failures == []
    assert r.ok


def test_malformed_cell_rejects_and_matches_twin():
    r = run_cell(_cell("malformed"))
    assert r.error == ""
    assert len(r.rejected) == 2, "oversized + empty must both be rejected"
    assert {u for u, _ in r.rejected} == {100_000, 100_001}
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["rejected"] == 2


def test_device_loss_cell_restarts_and_matches_twin():
    r = run_cell(_cell("device-loss"))
    assert r.error == ""
    assert r.restarts >= 1, "the simulated device loss must actually fire"
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["restarts"] == r.restarts


def test_fault_plan_registry():
    assert get_plan("none").name == "none"
    assert get_plan("device-loss").resilient
    assert not get_plan("preempt").resilient
    with pytest.raises(KeyError):
        get_plan("cosmic-ray")


# ---------------------------------------------------------------------------
# ledger recording + perf gate
# ---------------------------------------------------------------------------


def test_metrics_from_scenario_schema(preempt_result):
    metrics = metrics_from_scenario(preempt_result.report())
    (key, row), = metrics.items()
    assert key == preempt_result.cell.ledger_key
    assert key.startswith("scenario/")
    for name in ("tok_s", "slot_utilization", "ttft_p50_s", "ttft_p95_s",
                 "preemptions", "rejected", "restarts"):
        assert name in row, f"missing {name}"
    assert row["golden_ok"] is True and row["slo_ok"] is True


def test_recorded_cell_gates_against_its_own_trajectory(
        tmp_path, preempt_result):
    ledger = Ledger(str(tmp_path))
    first = record_cell(preempt_result, ledger=ledger)
    assert first.meta["sources"] == ["scenario"]
    # identical stats re-recorded: the per-cell gate must PASS (the
    # latest-comparable fallback pairs runs on the shared scenario/ key)
    second = record_cell(preempt_result, ledger=ledger)
    gate = gate_run(second, ledger, tuning_store=None)
    assert gate.ok, [r.describe() for r in gate.comparison.regressions]
    assert preempt_result.cell.ledger_key in second.metrics


def test_golden_flip_and_new_faults_regress(tmp_path, preempt_result):
    ledger = Ledger(str(tmp_path))
    good = metrics_from_scenario(preempt_result.report())
    ledger.record(good)
    key = preempt_result.cell.ledger_key
    bad = {key: dict(good[key], golden_ok=False,
                     rejected=good[key]["rejected"] + 1)}
    run = ledger.record(bad)
    gate = gate_run(run, ledger, tuning_store=None)
    assert not gate.ok
    names = {r.metric for r in gate.comparison.regressions}
    assert {"golden_ok", "rejected"} <= names


def test_slo_violation_fails_cell():
    cell = _cell("none", slo=SLOSpec(min_tok_s=1e12))
    r = run_cell(cell)
    assert r.error == "" and r.slo_failures and not r.ok


# ---------------------------------------------------------------------------
# runner + CLI surface
# ---------------------------------------------------------------------------


def test_run_matrix_only_filter_and_markdown(tmp_path):
    spec = _tiny_matrix(faults=["none", "malformed"])
    results = run_matrix(spec, only="*malformed", record=True,
                         ledger=Ledger(str(tmp_path)))
    assert [r.cell.fault for r in results] == ["malformed"]
    md = format_matrix_markdown(results)
    assert "| cell |" in md and results[0].cell.cell_id in md
    assert Ledger(str(tmp_path)).latest() is not None


def test_ttft_tracked_per_request(preempt_result):
    s = preempt_result.stats
    assert s["ttft_p50_s"] > 0.0
    assert s["ttft_p95_s"] >= s["ttft_p50_s"]
    assert s["ttft_p50_s"] <= s["p50_latency_s"] + 1e-9


def _cli_env():
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    return {**os.environ, "PYTHONPATH": src}


def test_cli_list_and_gate(tmp_path):
    env_cells = subprocess.run(
        [sys.executable, "-m", "repro.scenarios", "list",
         "--only", "*continuous/gpt2-124m/*"],
        capture_output=True, text=True, check=True, env=_cli_env())
    ids = env_cells.stdout.split()
    assert ids and all(i.endswith(("none", "preempt", "device-loss",
                                   "malformed")) for i in ids)

    out = tmp_path / "report.json"
    md = tmp_path / "matrix.md"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.scenarios", "gate",
         "--only", "*continuous/gpt2-124m/none",
         "--out", str(out), "--report-md", str(md)],
        capture_output=True, text=True, env=_cli_env())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "all cells ok" in proc.stdout
    report = json.loads(out.read_text())
    assert report["kind"] == "scenario_matrix"
    assert all(c["ok"] for c in report["cells"])
    assert md.read_text().startswith("# Scenario matrix")


def test_launch_serve_counts_rejections_instead_of_crashing(tmp_path):
    """Submit-time RequestTooLong must be counted and reported by the
    serve driver, never escape as a crash."""
    from repro.launch.serve import main as serve_main
    from repro.perf.ledger import metrics_from_serving

    out = tmp_path / "serve.json"
    # every sampled prompt (4..16 tokens) + a 100-token budget overflows
    # the 64-token slot cache: all submissions must be rejected
    rc = serve_main(["--requests", "3", "--max-new", "100",
                     "--max-len", "64", "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["rejected"] == 3
    assert len(report["rejections"]) == 3
    assert all("exceeds" in r["reason"] for r in report["rejections"])
    assert report["stats"]["requests"] == 0  # nothing reached the engine
    (_, row), = metrics_from_serving(report).items()
    assert row["rejected"] == 3


# ---------------------------------------------------------------------------
# prefill-chunking axis
# ---------------------------------------------------------------------------


def test_prefill_axis_expansion_and_shared_seed():
    """The chunk axis expands only under the continuous scheduler, tags
    cell ids with ``pc<C>``, and stays OUTSIDE the traffic key: every
    chunk width samples byte-identical traffic."""
    spec = _tiny_matrix(schedulers=["continuous", "wave"],
                        prefill_chunks=[1, 8], prefill_budget=8)
    cells = spec.cells()
    cont = [c for c in cells if c.scheduler == "continuous"]
    wave = [c for c in cells if c.scheduler == "wave"]
    assert sorted(c.prefill_chunk for c in cont) == [1, 8]
    assert [c.prefill_chunk for c in wave] == [1], "wave has no chunked path"
    chunked, = [c for c in cont if c.prefill_chunk == 8]
    plain, = [c for c in cont if c.prefill_chunk == 1]
    assert chunked.cell_id.endswith("/pc8")
    assert "pc" not in plain.cell_id
    assert chunked.prefill_budget == 8 and plain.prefill_budget is None
    assert len({c.seed for c in cells}) == 1, (
        "prefill chunking must not perturb traffic seeds"
    )
    t_plain = sample_trace(plain, vocab=256)
    t_chunk = sample_trace(chunked, vocab=256)
    for a, b in zip(t_plain, t_chunk):
        assert (a.uid, a.arrive_step, a.max_new_tokens) == (
            b.uid, b.arrive_step, b.max_new_tokens)
        np.testing.assert_array_equal(a.prompt, b.prompt)


def test_chunk_twin_is_token_by_token_and_fault_free():
    spec = _tiny_matrix(faults=["preempt"], prefill_chunks=[8],
                        prefill_budget=8)
    cell, = spec.cells()
    twin = cell.chunk_twin()
    assert twin.prefill_chunk == 1 and twin.prefill_budget is None
    assert twin.fault == "none"
    assert twin.seed == cell.seed
    assert "pc" not in twin.cell_id


def test_chunked_preempt_cell_matches_both_twins():
    """The hardest cell on the axis: chunked prefill + mid-flight
    preemption must match the fault-free twin AND the token-by-token
    chunk twin, uid-for-uid."""
    spec = _tiny_matrix(faults=["preempt"], prefill_chunks=[8],
                        prefill_budget=8)
    cell, = spec.cells()
    r = run_cell(cell)
    assert r.error == ""
    assert r.stats["preemptions"] >= 1
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["prefill_chunk"] == 8
    rep = r.report()
    assert rep["prefill_chunk"] == 8 and rep["prefill_budget"] == 8
    metrics = metrics_from_scenario(rep)
    (key, row), = metrics.items()
    assert key.endswith("/pc8")
    assert row["prefill_chunk"] == 8
    assert row["ttft_p95_steps"] >= 1.0


def test_slo_ttft_steps_ceiling():
    """max_ttft_p95_steps is opt-in: None never checks (even when the
    metric is absent), a finite ceiling gates the deterministic value."""
    loose = SLOSpec()
    assert loose.check({"tok_s": 9.0, "p95_latency_s": 0.1,
                        "ttft_p95_s": 0.1, "slot_utilization": 0.9}) == []
    tight = SLOSpec(max_ttft_p95_steps=4.0)
    ok = {"tok_s": 9.0, "p95_latency_s": 0.1, "ttft_p95_s": 0.1,
          "slot_utilization": 0.9, "ttft_p95_steps": 3.0}
    assert tight.check(ok) == []
    msgs = tight.check(dict(ok, ttft_p95_steps=9.0))
    assert len(msgs) == 1 and "TTFT steps" in msgs[0]
    cell = _cell("none", prefill_chunks=[8], prefill_budget=8,
                 slo=SLOSpec(max_ttft_p95_steps=0.0))
    r = run_cell(cell)
    assert r.error == "" and r.slo_failures and not r.ok


def test_smoke_matrix_unaffected_by_prefill_axis():
    """The CI smoke matrix stays on the token-by-token path with the
    exact same cell ids and seeds as before the axis existed."""
    for c in smoke_matrix().cells():
        assert c.prefill_chunk == 1 and c.prefill_budget is None
        assert "pc" not in c.cell_id


def test_sharing_axis_expansion_and_shared_traffic():
    """The prompt_sharing axis expands only under the continuous
    scheduler, tags cell ids, keeps the sharing MODE out of the traffic
    key ("shared" and "shared-off" serve byte-identical requests) while
    the traffic SHAPE (bimodal shared prefixes) is in it."""
    spec = _tiny_matrix(schedulers=["continuous", "wave"],
                        prompt_sharing=["none", "shared"])
    cells = spec.cells()
    cont = [c for c in cells if c.scheduler == "continuous"]
    wave = [c for c in cells if c.scheduler == "wave"]
    assert sorted(c.prompt_sharing for c in cont) == ["none", "shared"]
    assert [c.prompt_sharing for c in wave] == ["none"]
    shared, = [c for c in cont if c.prompt_sharing == "shared"]
    plain, = [c for c in cont if c.prompt_sharing == "none"]
    assert shared.cell_id.endswith("/shared")
    assert "shared" not in plain.cell_id
    assert shared.share_prefixes and not plain.share_prefixes
    # the twin: same seed, fault-free, sharing disabled on the SAME trace
    twin = shared.sharing_twin()
    assert twin.prompt_sharing == "shared-off" and twin.fault == "none"
    assert twin.seed == shared.seed
    assert not twin.share_prefixes
    t_on = sample_trace(shared, vocab=256)
    t_off = sample_trace(twin, vocab=256)
    for a, b in zip(t_on, t_off):
        assert (a.uid, a.arrive_step, a.max_new_tokens) == (
            b.uid, b.arrive_step, b.max_new_tokens)
        np.testing.assert_array_equal(a.prompt, b.prompt)
    # shared-prefix traffic differs from the plain cell's (shape is keyed)
    assert shared.seed != plain.seed
    # bimodal by construction: at most 2 distinct prompt prefixes
    firsts = {tuple(r.prompt[:4]) for r in t_on}
    assert len(firsts) <= 2, firsts


def test_shared_cell_matches_sharing_off_twin_with_fewer_blocks():
    """A shared-prefix cell runs against its sharing-off twin: identical
    streams (golden), strictly fewer physical blocks, dedup > 1 — and the
    ledger row lands under the sharing-tagged scenario key."""
    spec = _tiny_matrix(prompt_sharing=["shared"],
                        prompts=[PromptSpec(kind="uniform", lo=8, hi=14)])
    cell, = spec.cells()
    r = run_cell(cell)
    assert r.error == ""
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["share_prefixes"] is True
    assert r.stats["shared_block_hits"] > 0
    assert r.stats["block_dedup_ratio"] > 1.0
    rep = r.report()
    assert rep["prompt_sharing"] == "shared"
    metrics = metrics_from_scenario(rep)
    (key, row), = metrics.items()
    assert key.endswith("/shared")
    assert row["block_dedup_ratio"] > 1.0
    assert row["physical_blocks"] < row["logical_blocks"]


def test_shared_preempt_cell_matches_both_twins():
    """Sharing + mid-flight preemption: the preempted COW cell must match
    its fault-free golden twin AND its sharing-off twin — the decref-not-
    free preemption contract under shared blocks, end to end."""
    spec = _tiny_matrix(faults=["preempt"], prompt_sharing=["shared"],
                        prompts=[PromptSpec(kind="uniform", lo=8, hi=14)])
    cell, = spec.cells()
    r = run_cell(cell)
    assert r.error == ""
    assert r.stats["preemptions"] >= 1
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["block_dedup_ratio"] > 1.0


def test_smoke_matrix_unaffected_by_sharing_axis():
    """The CI smoke matrix keeps sharing off with the exact same cell ids
    and seeds as before the axis existed."""
    for c in smoke_matrix().cells():
        assert c.prompt_sharing == "none" and not c.share_prefixes
        assert not c.cell_id.endswith("/shared")


def test_cli_gate_fails_on_no_match():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.scenarios", "gate",
         "--only", "no-such-cell"],
        capture_output=True, text=True, env=_cli_env())
    assert proc.returncode == 2


# ---------------------------------------------------------------------------
# device-mesh axis (tensor-parallel serving)
# ---------------------------------------------------------------------------


def test_mesh_axis_expansion_and_shared_traffic():
    from repro.launch.mesh import MeshShapeError
    from repro.scenarios.matrix import full_matrix

    spec = _tiny_matrix(schedulers=["wave", "continuous"],
                        meshes=[None, "1x1"])
    cells = spec.cells()
    # wave never shards (the paged continuous path owns the mesh)
    assert not [c for c in cells if c.mesh and c.scheduler == "wave"]
    meshed = [c for c in cells if c.mesh == "1x1"]
    plain = [c for c in cells if c.mesh is None
             and c.scheduler == "continuous"]
    assert len(meshed) == 1 and len(plain) == 1
    # the mesh axis is outside the traffic key: twin pairs sample
    # byte-identical requests, and the cell id grows an m<DxM> segment
    assert meshed[0].traffic_key == plain[0].traffic_key
    assert meshed[0].seed == plain[0].seed
    assert meshed[0].cell_id == plain[0].cell_id + "/m1x1"
    assert meshed[0].mesh_twin().cell_id == plain[0].cell_id
    # junk shapes die at construction, not at serve time
    with pytest.raises(MeshShapeError):
        dataclasses.replace(meshed[0], mesh="2x2x2")
    # the wide matrix carries the mesh axis; the CI smoke matrix doesn't
    assert any(c.mesh == "1x1" for c in full_matrix().cells())
    assert all(c.mesh is None for c in smoke_matrix().cells())


def test_mesh_cell_matches_unsharded_twin():
    r = run_cell(_cell(meshes=["1x1"]))
    assert r.error == ""
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["mesh"] == "1x1"
    assert r.stats["mesh_devices"] == 1
    assert r.stats["device_lane_utilization"] > 0
    assert r.report()["mesh"] == "1x1"


def test_mesh_device_loss_cell_restarts_resharded():
    # device loss on a meshed cell: the resilient loop rebuilds the
    # engine (re-entering the mesh cache is the resharding-on-restart
    # path) and the streams still match the fault-free unsharded twin
    r = run_cell(_cell("device-loss", meshes=["1x1"]))
    assert r.error == ""
    assert r.restarts >= 1, "the simulated device loss must actually fire"
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["mesh"] == "1x1"
    assert r.stats["device_lane_utilization"] > 0


def test_mesh_cell_ledger_key_forks(tmp_path):
    cell = _cell(meshes=["1x1"])
    r = run_cell(cell)
    rows = metrics_from_scenario(r.report())
    (key,) = rows
    assert key == f"scenario/{cell.cell_id}"
    assert key.endswith("/m1x1")
    assert rows[key]["device_lane_utilization"] > 0
