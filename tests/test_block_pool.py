"""Property tests: the refcounted prefix-sharing block pool, fuzzed to
destruction.

The pool is pure host-side bookkeeping, so we can hammer it with
thousands of random submit / decode / EOS-free interleavings (the exact
op mix the continuous scheduler emits) and check the full invariant set
after EVERY operation:

* conservation — every non-null block is exactly one of {free, referenced};
* no aliasing past divergence — a generated-token write only ever lands
  in a refcount-1 block (COW first when shared);
* sharing is content-true — a shared acquire returns a block whose
  registered token chain is byte-identical to the joiner's prompt span;
* the registry never lies — every registered claim matches the shadow
  content byte-for-byte after EVERY op, including in-place generated
  writes (modeled with a sentinel the engine's
  ``note_generated_write`` trim hook must keep out of every claim);
* no double free, no incref on dead blocks, null block never allocated;
* dedup accounting — ``physical <= logical``, ratio >= 1, and counters
  reconcile with the shadow model.

The numpy fuzzer runs >= 500 independent interleavings and prints the
failing round's seed (override the master seed with ``REPRO_FUZZ_SEED``
to replay).  When ``hypothesis`` is installed (the CI ``[test]`` extra
ships it; it is optional locally) the same driver runs under
shrinking, so a failure minimizes to the shortest op sequence.
"""

import math
import os

import numpy as np
import pytest

from repro.serve.block_pool import NULL_BLOCK, BlockPool

try:  # optional: CI installs it via the [test] extra, local envs may not
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# the shared fuzz driver: one op list -> one pool lifecycle, fully checked
# ---------------------------------------------------------------------------

_VOCAB = 7
#: two long shared prefixes (the bimodal system-prompt shape) with lengths
#: that hit both block-aligned and ragged last spans at block_size=4
_PREFIXES = ((1, 2, 3, 4, 5, 6, 1, 2), (2, 4, 6, 1, 3))


def _drive(ops, *, n_blocks=24, bs=4, share=True):
    """Replay ``ops`` — a list of ``(kind, value)`` with kind in
    {submit, decode, finish} — against a BlockPool, modeling exactly what
    the engine's continuous scheduler does with it, and assert the full
    invariant set after every single operation.

    ``value`` deterministically selects the request / tokens involved, so
    the same op list always replays the same lifecycle (hypothesis can
    shrink it; the numpy fuzzer can regenerate it from a seed).
    """
    pool = BlockPool(n_blocks, bs, share_prefixes=share)
    live = {}     # uid -> {"prompt": tuple, "blocks": [blk...], "pos": int}
    content = {}  # blk -> the exact token chain the block's rows encode
    next_uid = 0
    max_pos = 5 * bs  # cap decode depth so rounds terminate

    def spans(n):
        return math.ceil(n / bs)

    def check():
        pool.check_invariants()
        # content-vs-key consistency: every registered claim must match
        # the shadow bytes exactly — THE oracle for the stale-partial-key
        # bug, where an in-place generated write (modeled as a "GEN"
        # sentinel below) diverges a block its registry key still claims
        for claim, blk in pool.registered_claims():
            got = content.get(blk, ())
            assert got[: len(claim)] == claim, (
                f"stale registry claim on block {blk}: "
                f"claims {claim}, rows hold {got}"
            )

    def finish(uid):
        st_ = live.pop(uid)
        for blk in st_["blocks"]:
            pool.decref(blk)
            if pool.refcount_of(blk) == 0:  # shadow follows the eviction
                content.pop(blk, None)

    for kind, v in ops:
        if kind == "submit":
            g = v % (len(_PREFIXES) + 1)
            tail_len = (v // 3) % 3  # 0..2 unique-tail tokens
            tail = tuple((v // (3 ** (1 + i))) % _VOCAB
                         for i in range(tail_len))
            base = _PREFIXES[g] if g < len(_PREFIXES) else \
                tuple((v + i) % _VOCAB for i in range(1 + v % 6))
            prompt = base + tail
            if len(pool.free) < spans(len(prompt)):
                if live:  # full pool: evict instead (what preempt does)
                    finish(sorted(live)[v % len(live)])
                check()
                continue
            blocks = []
            for j in range(spans(len(prompt))):
                blk = pool.acquire(prompt, j)
                assert blk != NULL_BLOCK
                if blk in content:  # shared hit: content must match exactly
                    end = min((j + 1) * bs, len(prompt))
                    assert content[blk][: end] == prompt[:end], (
                        f"aliased block {blk}: holds {content[blk]}, "
                        f"joiner wants {prompt[:end]}"
                    )
                    assert pool.refcount_of(blk) >= 2
                else:
                    content[blk] = prompt[: min((j + 1) * bs, len(prompt))]
                    assert pool.refcount_of(blk) == 1
                blocks.append(blk)
            live[next_uid] = {"prompt": prompt, "blocks": blocks,
                              "pos": len(prompt)}
            next_uid += 1
        elif kind == "decode" and live:
            uid = sorted(live)[v % len(live)]
            st_ = live[uid]
            if st_["pos"] >= max_pos:
                finish(uid)
                check()
                continue
            j = st_["pos"] // bs
            if j >= len(st_["blocks"]):  # crossed into a fresh span
                if not pool.free:
                    finish(uid)
                    check()
                    continue
                blk = pool.acquire(st_["prompt"], j)
                # generated-only spans are NEVER shared or registered
                assert pool.refcount_of(blk) == 1 and blk not in content
                st_["blocks"].append(blk)
            blk = st_["blocks"][j]
            if pool.refcount_of(blk) > 1:  # divergence: COW before writing
                if not pool.free:
                    finish(uid)
                    check()
                    continue
                new = pool.cow(blk)
                assert new != blk and new != NULL_BLOCK
                assert pool.refcount_of(new) == 1
                st_["blocks"][j] = new
                content.pop(new, None)  # private now: chain no longer valid
                blk = new
            # THE no-aliasing-past-divergence property: a generated token
            # only ever lands in a block this slot owns exclusively
            assert pool.refcount_of(blk) == 1, (
                f"generated write into shared block {blk} "
                f"(refcount {pool.refcount_of(blk)})"
            )
            # the in-place generated write itself: mirror the engine's
            # stale-key trim hook, and poison the shadow content from
            # this row on — check() then proves no registry key ever
            # claims a generated byte as prompt content
            pool.note_generated_write(blk, st_["pos"] % bs)
            if blk in content:
                content[blk] = content[blk][: st_["pos"]] + ("GEN",)
            st_["pos"] += 1
        elif kind == "finish" and live:
            finish(sorted(live)[v % len(live)])
        check()
        assert pool.physical_blocks <= pool.logical_blocks
        assert pool.dedup_ratio >= 1.0

    # drain: every request releases its blocks; the pool must come back whole
    for uid in sorted(live):
        finish(uid)
    check()
    assert all(c == 0 for c in pool.refcount)
    assert len(pool.free) == n_blocks - 1
    return pool


def _random_ops(seed, n_ops=30):
    rng = np.random.default_rng(seed)
    kinds = rng.choice(["submit", "decode", "decode", "finish"], size=n_ops)
    vals = rng.integers(0, 2 ** 16, size=n_ops)
    return [(str(k), int(v)) for k, v in zip(kinds, vals)]


# ---------------------------------------------------------------------------
# the numpy fuzzer: >= 500 independent interleavings, replayable by seed
# ---------------------------------------------------------------------------

N_ROUNDS = 500


def test_fuzz_pool_lifecycle_500_interleavings():
    """500 seeded random interleavings of submit/decode/EOS/preempt-free,
    every invariant checked after every op.  On failure the round seed is
    printed — replay one round with REPRO_FUZZ_SEED=<seed>."""
    master = int(os.environ.get("REPRO_FUZZ_SEED", "0"))
    if "REPRO_FUZZ_SEED" in os.environ:
        _drive(_random_ops(master, n_ops=60))
        return
    shared_any = False
    for i in range(N_ROUNDS):
        seed = master * 100003 + i
        try:
            pool = _drive(_random_ops(seed))
        except AssertionError as e:  # pragma: no cover - failure path
            pytest.fail(
                f"pool invariant broken in round {i} "
                f"(replay: REPRO_FUZZ_SEED={seed}): {e}"
            )
        shared_any |= pool.shared_hits > 0
    # the op mix must actually exercise sharing, or the fuzz is a no-op
    assert shared_any, "no round ever produced a shared hit"


def test_fuzz_sharing_disabled_is_plain_lifo():
    """With sharing off the pool must be a plain LIFO allocator: same op
    streams, zero shared hits, dedup ratio exactly 1."""
    for i in range(50):
        pool = _drive(_random_ops(7_000 + i), share=False)
        assert pool.shared_hits == 0 and pool.cow_copies == 0
        assert pool.dedup_ratio == 1.0
        assert pool.logical_blocks == pool.physical_blocks


if HAVE_HYPOTHESIS:
    _OPS = st.lists(
        st.tuples(st.sampled_from(["submit", "decode", "finish"]),
                  st.integers(0, 2 ** 16)),
        max_size=80,
    )

    @settings(max_examples=200, deadline=None, derandomize=True,
              print_blob=True)
    @given(ops=_OPS)
    def test_fuzz_pool_lifecycle_hypothesis(ops):
        """The same driver under hypothesis: failures shrink to the
        minimal op sequence (derandomized so CI is reproducible; the
        failure database is uploaded as a CI artifact)."""
        _drive(ops)
else:  # pragma: no cover - hypothesis present in CI
    @pytest.mark.skip(reason="hypothesis not installed (CI [test] extra)")
    def test_fuzz_pool_lifecycle_hypothesis():
        pass


# ---------------------------------------------------------------------------
# pinned unit traces: each sharing/COW rule on a hand-checked lifecycle
# ---------------------------------------------------------------------------


def test_identical_prompts_share_every_span():
    pool = BlockPool(8, 4, share_prefixes=True)
    prompt = (1, 2, 3, 4, 5, 6)  # one full span + one ragged span
    a = [pool.acquire(prompt, j) for j in range(2)]
    b = [pool.acquire(prompt, j) for j in range(2)]
    assert a == b
    assert [pool.refcount_of(x) for x in a] == [2, 2]
    assert pool.logical_blocks == 4 and pool.physical_blocks == 2
    assert pool.shared_hits == 2 and pool.dedup_ratio == 2.0
    pool.check_invariants()


def test_divergent_tail_shares_only_the_common_span():
    pool = BlockPool(8, 4, share_prefixes=True)
    a = [pool.acquire((1, 2, 3, 4, 5, 6), j) for j in range(2)]
    b = [pool.acquire((1, 2, 3, 4, 9, 9), j) for j in range(2)]
    assert b[0] == a[0] and b[1] != a[1]  # full span shared, ragged not
    assert pool.refcount_of(a[0]) == 2 and pool.refcount_of(a[1]) == 1
    pool.check_invariants()


def test_partial_tail_prefix_shares_but_longer_tail_does_not():
    pool = BlockPool(8, 4, share_prefixes=True)
    reg = pool.acquire((1, 2, 3, 4, 5, 6), 1)     # registered tail (5, 6)
    assert pool.acquire((1, 2, 3, 4, 5), 1) == reg       # tail (5,) subset
    assert pool.acquire((1, 2, 3, 4, 5, 6, 7), 1) != reg  # longer: rejected
    pool.check_invariants()


def test_stale_partial_key_trimmed_on_inplace_generated_write():
    """THE partial-tail soundness regression: registrant tail (5, 6),
    joiner tail (5,) — the registrant frees, the joiner (now sole owner,
    so no COW) writes its first generated token in place at row 1.  The
    registered (.., (5, 6)) key now claims a generated byte as prompt
    content; before the trim hook, a later (5, 6) prompt aliased the
    diverged block and its write-through corrupted the owner's stream."""
    pool = BlockPool(8, 4, share_prefixes=True)
    reg = pool.acquire((1, 2, 3, 4, 5, 6), 1)   # registers tail (5, 6)
    join = pool.acquire((1, 2, 3, 4, 5), 1)     # tail (5,): strict prefix
    assert join == reg and pool.refcount_of(reg) == 2
    pool.decref(reg)                            # registrant finishes
    assert pool.refcount_of(reg) == 1           # joiner owns it alone
    # the joiner's first generated token: position 5 -> row 1, no COW
    pool.note_generated_write(reg, 1)
    pool.check_invariants()
    # the stale (5, 6) claim is gone: a byte-identical later prompt must
    # allocate fresh instead of aliasing the diverged row
    assert pool.acquire((1, 2, 3, 4, 5, 6), 1) != reg
    # ...but row 0 still holds the claimed prompt byte, so the trimmed
    # (5,) key keeps sharing sound prefixes
    assert pool.acquire((1, 2, 3, 4, 5), 1) == reg
    pool.check_invariants()


def test_inplace_write_past_registered_tail_keeps_the_key():
    """An owner whose prompt tail EQUALS the registered tail generates
    strictly past the claimed rows, so the key survives untrimmed and a
    later identical prompt still shares the block."""
    pool = BlockPool(8, 4, share_prefixes=True)
    reg = pool.acquire((1, 2, 3, 4, 5, 6), 1)  # tail (5, 6): rows 0-1
    pool.note_generated_write(reg, 2)          # first generated row: 2
    assert pool.acquire((1, 2, 3, 4, 5, 6), 1) == reg
    pool.check_invariants()


def test_pool_exhaustion_raises_descriptive():
    """An empty free list surfaces as a typed, descriptive error from
    both alloc() and cow() — never a bare IndexError — and a failed
    cow() leaves the pool state untouched."""
    pool = BlockPool(3, 4)  # 2 usable blocks
    pool.alloc()
    pool.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc()
    pool.check_invariants()

    pool2 = BlockPool(3, 4, share_prefixes=True)
    prompt = (1, 2, 3, 4)
    a = pool2.acquire(prompt, 0)
    assert pool2.acquire(prompt, 0) == a  # shared: refcount 2
    pool2.alloc()  # drain the free list
    with pytest.raises(RuntimeError, match="exhausted"):
        pool2.cow(a)
    assert pool2.refcount_of(a) == 2  # the failed cow changed nothing
    pool2.check_invariants()


def test_cow_detaches_and_decrefs_the_shared_block():
    pool = BlockPool(8, 4, share_prefixes=True)
    prompt = (1, 2, 3, 4)
    a = pool.acquire(prompt, 0)
    b = pool.acquire(prompt, 0)
    assert a == b and pool.refcount_of(a) == 2
    new = pool.cow(a)
    assert new != a
    assert pool.refcount_of(new) == 1 and pool.refcount_of(a) == 1
    assert pool.cow_copies == 1 and pool.physical_blocks == 2
    # a now-private block refuses a second COW
    with pytest.raises(RuntimeError):
        pool.cow(new)
    pool.check_invariants()


def test_eviction_clears_the_registry_for_reuse():
    """Freeing the last sharer evicts the lookup keys: the next identical
    prompt allocates fresh (the old bytes are gone) and the block itself
    returns to the head of the free list (LIFO)."""
    pool = BlockPool(8, 4, share_prefixes=True)
    prompt = (1, 2, 3, 4)
    a = pool.acquire(prompt, 0)
    pool.decref(a)
    assert pool.refcount_of(a) == 0 and pool.free[0] == a
    b = pool.acquire(prompt, 0)
    assert b == a  # LIFO reuse of the physical id...
    assert pool.shared_hits == 0  # ...but via a fresh allocation, not a hit
    pool.check_invariants()


def test_generated_spans_never_register():
    """A span past the prompt (generated tokens) allocates privately even
    with sharing on, and a later identical prompt cannot alias it."""
    pool = BlockPool(8, 4, share_prefixes=True)
    prompt = (1, 2, 3, 4)
    pool.acquire(prompt, 0)
    gen = pool.acquire(prompt, 1)  # span start 4 >= len(prompt)
    assert pool.refcount_of(gen) == 1
    other = pool.acquire(prompt, 1)
    assert other != gen
    pool.check_invariants()


def test_double_free_and_dead_incref_raise_typed():
    pool = BlockPool(4, 4, share_prefixes=True)
    blk = pool.alloc()
    pool.decref(blk)
    with pytest.raises(RuntimeError):
        pool.decref(blk)
    with pytest.raises(RuntimeError):
        pool.incref(blk)
    with pytest.raises(RuntimeError):
        pool.decref(NULL_BLOCK)
    pool.check_invariants()


def test_unshared_pool_matches_reference_lifo_allocator():
    """share_prefixes=False must be bit-compatible with the engine's
    original deque discipline — block ids included."""
    from collections import deque

    pool = BlockPool(10, 4, share_prefixes=False)
    ref = deque(range(1, 10))
    rng = np.random.default_rng(3)
    held = []
    for _ in range(200):
        if held and (not ref or rng.random() < 0.5):
            i = int(rng.integers(0, len(held)))
            blk = held.pop(i)
            pool.decref(blk)
            ref.appendleft(blk)
        elif ref:
            got = pool.acquire((1, 2, 3, 4, 5, 6, 7, 8), 0)
            assert got == ref.popleft()
            held.append(got)
        assert list(pool.free) == list(ref)
        pool.check_invariants()
