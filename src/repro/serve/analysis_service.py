"""Analysis-as-a-service: the one-call SVE pipeline behind a request queue.

Mirrors :class:`repro.serve.engine.ServeEngine`'s legacy wave scheduler —
submit requests, admit them in waves of up to ``max_batch``, drain until the queue
is empty — but the unit of work is an *analysis request* (workload x chips x
dtypes) instead of a decode request.  All waves share one
:class:`~repro.analysis.pipeline.ArtifactCache`, by default backed by the
persistent :class:`~repro.analysis.store.ArtifactStore`, so:

* requests naming the same workload in one wave (or across waves) trigger a
  single compile (single-flight),
* a service restart re-serves previously analyzed workloads with zero
  compiles (store hit), and
* ``jobs > 1`` fans each wave's cells over a thread pool.

CLI (emits a JSON report suitable as a ``BENCH_*.json`` trajectory point):

    python -m repro.serve.analysis_service \\
        --workloads kernel/gemm kernel/spmv --chips grace-core tpu-v5e \\
        --jobs 4 --out report.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis.pipeline import (
    DEFAULT_STORE,
    ArtifactCache,
    SVEAnalysis,
    analyze,
    format_table,
)
from repro.analysis.store import ArtifactStore
from repro.analysis.workload import Workload, get_workload, list_workloads
from repro.core import hw


@dataclasses.dataclass
class AnalysisRequest:
    """One queued unit of analysis: a workload swept over chips x dtypes."""

    uid: int
    workload: Union[str, Workload]
    chips: Tuple[str, ...] = ("grace-core",)
    dtypes: Optional[Tuple[str, ...]] = None
    source: str = "auto"
    time_roi: bool = False

    def __post_init__(self) -> None:
        self.results: List[SVEAnalysis] = []
        self.error: Optional[str] = None
        self.done = False

    @property
    def name(self) -> str:
        wl = self.workload
        return wl if isinstance(wl, str) else wl.name

    def cells(self) -> List[Tuple[Workload, hw.ChipSpec, str]]:
        wl = get_workload(self.workload) if isinstance(self.workload, str) else self.workload
        out = []
        for chip_name in self.chips:
            chip = hw.get_chip(chip_name)
            for dtype in self.dtypes or (wl.dtype,):
                out.append((wl, chip, dtype))
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "uid": self.uid,
            "workload": self.name,
            "chips": list(self.chips),
            "dtypes": list(self.dtypes) if self.dtypes else None,
            "source": self.source,
            "error": self.error,
            "results": [r.to_dict() for r in self.results],
        }


class AnalysisService:
    """Queue/wave engine serving SVE analyses against a shared store."""

    def __init__(
        self,
        *,
        max_batch: int = 8,
        jobs: int = 1,
        cache: Optional[ArtifactCache] = None,
        store: Union[ArtifactStore, str, None] = None,
    ) -> None:
        self.max_batch = max_batch
        self.jobs = max(int(jobs), 1)
        self.cache = cache or ArtifactCache(
            store=store if store is not None else DEFAULT_STORE
        )
        self.queue: deque = deque()
        self.completed: Dict[int, AnalysisRequest] = {}
        self.waves = 0
        self.wall_s = 0.0
        self._next_uid = 0

    # -- submission -----------------------------------------------------------

    def submit(
        self,
        workload: Union[str, Workload, AnalysisRequest],
        *,
        chips: Sequence[str] = ("grace-core",),
        dtypes: Optional[Sequence[str]] = None,
        source: str = "auto",
        time_roi: bool = False,
    ) -> AnalysisRequest:
        """Enqueue one request; returns it (uid assigned here)."""
        if isinstance(workload, AnalysisRequest):
            req = workload
        else:
            req = AnalysisRequest(
                uid=-1,
                workload=workload,
                chips=tuple(chips),
                dtypes=tuple(dtypes) if dtypes else None,
                source=source,
                time_roi=time_roi,
            )
        req.uid = self._next_uid
        self._next_uid += 1
        self.queue.append(req)
        return req

    # -- one wave -------------------------------------------------------------

    def _run_wave(self, wave: List[AnalysisRequest]) -> None:
        """Batch the wave's requests into one fan-out against the shared
        cache: cells from different requests interleave freely; cells naming
        the same workload dedupe to one compile (single-flight)."""
        plan: List[Tuple[AnalysisRequest, Workload, hw.ChipSpec, str]] = []
        for req in wave:
            try:
                for wl, chip, dtype in req.cells():
                    plan.append((req, wl, chip, dtype))
            except Exception as e:  # noqa: BLE001 — unknown name, failing
                # lazy builder, bad shape math: fail THIS request only
                req.error = str(e)

        def run_cell(item):
            req, wl, chip, dtype = item
            # a cell that fails to trace/compile/analyze must not take the
            # drain (and every other in-flight request) down with it
            try:
                return analyze(
                    wl,
                    chip,
                    dtype=dtype,
                    source=req.source,
                    time_roi=req.time_roi,
                    cache=self.cache,
                )
            except Exception as e:  # noqa: BLE001 — reported per request
                return e

        if self.jobs > 1 and len(plan) > 1:
            with ThreadPoolExecutor(max_workers=self.jobs) as pool:
                results = list(pool.map(run_cell, plan))
        else:
            results = [run_cell(item) for item in plan]
        for (req, _, chip, dtype), res in zip(plan, results):
            if isinstance(res, Exception):
                err = f"{req.name}@{chip.name}/{dtype}: {type(res).__name__}: {res}"
                req.error = req.error or err
            else:
                req.results.append(res)
        for req in wave:
            req.done = True
            self.completed[req.uid] = req

    # -- public ---------------------------------------------------------------

    def run_until_drained(self, max_waves: int = 1000) -> Dict[int, AnalysisRequest]:
        waves = 0
        t0 = time.perf_counter()
        while self.queue:
            wave = [
                self.queue.popleft()
                for _ in range(min(self.max_batch, len(self.queue)))
            ]
            self._run_wave(wave)
            self.waves += 1
            waves += 1
            if waves > max_waves:
                raise RuntimeError("analysis service loop did not drain")
        self.wall_s += time.perf_counter() - t0
        return self.completed

    def perf_ledger(self):
        """The perf ledger that corresponds to THIS service's store: the
        ``perf/`` sibling of its artifact directory (matching the default
        layout, where the ledger lives under the events store's root), or
        the process default when the service runs store-less."""
        import os

        from repro.perf import Ledger, default_ledger

        store = self.cache.store
        if store is None:
            return default_ledger()
        return Ledger(os.path.join(store.cache_dir, "perf"))

    def _trajectory(self) -> Dict[str, Any]:
        """Perf-ledger context for this report: how many trajectory points
        this service's ledger holds and the latest run id, so a consumer
        can line this report up against the recorded history.  Advisory —
        never raises (an empty/unreadable ledger reports zero runs)."""
        try:
            runs = self.perf_ledger().runs()
            return {
                "runs": len(runs),
                "latest_run_id": runs[-1].run_id if runs else None,
                "series": sorted({r.env.series_key() for r in runs}),
            }
        except Exception:  # noqa: BLE001 — trajectory context is advisory
            return {"runs": 0, "latest_run_id": None, "series": []}

    def report(self) -> Dict[str, Any]:
        """Machine-readable drain report (a BENCH_*.json trajectory point).

        ``schema`` versions this report's shape so downstream consumers can
        evolve with the trajectory format (bump it on breaking changes).
        ``tuning`` summarizes the autotuner outlook of every kernel cell
        served: per (kernel, chip, dtype), the roofline-best block config,
        its predicted speedup over the kernel's hard-coded default, and the
        persisted tuned config when the tuning store holds one.
        ``trajectory`` is the perf ledger's current state; the CLI's
        ``--record`` appends this very report to that ledger and stamps the
        resulting ``run_id`` into the payload.
        """
        reqs = [self.completed[uid].to_dict() for uid in sorted(self.completed)]
        n_cells = sum(len(r["results"]) for r in reqs)
        tuned: Dict[str, Any] = {}
        for uid in sorted(self.completed):
            for res in self.completed[uid].results:
                t = res.tuning
                if not t:
                    continue
                key = f"{t['kernel']}@{res.chip}/{res.dtype}"
                tuned[key] = {
                    "best_config": t["best_config"],
                    "predicted_speedup": t["predicted_speedup"],
                    "record": t["record"],
                }
        return {
            "kind": "analysis_service_report",
            "schema": 1,
            "requests": reqs,
            "tuning": tuned,
            "trajectory": self._trajectory(),
            "service": {
                "requests": len(reqs),
                "cells": n_cells,
                "waves": self.waves,
                "max_batch": self.max_batch,
                "jobs": self.jobs,
                "wall_s": self.wall_s,
                "compiles": self.cache.compiles,
                "cache_hits": self.cache.hits,
                "store_hits": self.cache.store_hits,
                "errors": sum(1 for r in reqs if r["error"]),
            },
        }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.analysis_service",
        description="Serve a batch of SVE analyses; emit a JSON report.",
    )
    ap.add_argument("--workloads", nargs="+", default=None,
                    help="workload names (default: every registered workload)")
    ap.add_argument("--chips", nargs="+", default=["grace-core"],
                    choices=sorted(hw.CHIPS), help="chip models to sweep")
    ap.add_argument("--dtypes", nargs="+", default=None,
                    help="ELEN sweep (default: each workload's own dtype)")
    ap.add_argument("--source", default="auto",
                    choices=["auto", "analytic", "compiled"])
    ap.add_argument("--jobs", type=int, default=1,
                    help="thread-pool width per wave")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="requests admitted per wave")
    ap.add_argument("--time-roi", action="store_true",
                    help="profiler-time each workload's ROI")
    ap.add_argument("--store-dir", default=None,
                    help="artifact store directory (default: "
                         "$REPRO_ARTIFACT_DIR or ~/.cache/repro/artifacts)")
    ap.add_argument("--no-store", action="store_true",
                    help="memory-only cache; never touch the disk store")
    ap.add_argument("--record", action="store_true",
                    help="append this report to the perf trajectory ledger "
                         "(repro.perf) and stamp its run_id into the payload")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here (default: stdout)")
    ap.add_argument("--list", action="store_true",
                    help="list registered workloads and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in list_workloads():
            print(name)
        return 0

    store: Union[ArtifactStore, str, None]
    if args.no_store:
        store = None
        cache = ArtifactCache()
    else:
        store = ArtifactStore(args.store_dir) if args.store_dir else DEFAULT_STORE
        cache = ArtifactCache(store=store)

    service = AnalysisService(
        max_batch=args.max_batch, jobs=args.jobs, cache=cache
    )
    known = set(list_workloads())
    names = args.workloads if args.workloads else sorted(known)
    unknown = [n for n in names if n not in known]
    if unknown:
        print(f"error: unknown workloads {unknown}; see --list", file=sys.stderr)
        return 2
    for name in names:
        service.submit(name, chips=args.chips, dtypes=args.dtypes,
                       source=args.source, time_roi=args.time_roi)
    service.run_until_drained()
    report = service.report()

    if args.record:
        from repro.perf import capture_env

        if report["service"]["cells"] == 0:
            print("[perf ledger: nothing to record — every request errored]",
                  file=sys.stderr)
        else:
            # the RunEnv series must reflect what was actually served, or
            # gate/baseline resolution (series-scoped) never finds the run:
            # primary chip is the first swept; dtype is the single dtype the
            # cells share, else "mixed"
            dtypes = {
                res["dtype"]
                for req in report["requests"] for res in req["results"]
            }
            ledger = service.perf_ledger()  # rides --store-dir, not global state
            run = ledger.record_sources(
                analyses=report,
                env=capture_env(
                    chip=args.chips[0],
                    dtype=dtypes.pop() if len(dtypes) == 1 else "mixed",
                ),
                meta={"kind": "analysis_service"},
            )
            report["run_id"] = run.run_id
            report["trajectory"] = service._trajectory()  # now includes this run
            print(f"[perf ledger: recorded run {run.run_id[:12]} "
                  f"(seq {run.seq}) -> {ledger.root}]", file=sys.stderr)

    results = [r for req in service.completed.values() for r in req.results]
    print(format_table(results), file=sys.stderr)
    svc = report["service"]
    print(
        f"[{svc['requests']} requests / {svc['cells']} cells in "
        f"{svc['waves']} waves: {svc['compiles']} compiles, "
        f"{svc['store_hits']} store hits, {svc['wall_s']:.2f}s]",
        file=sys.stderr,
    )
    payload = json.dumps(report, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
        print(f"report -> {args.out}", file=sys.stderr)
    else:
        print(payload)
    return 1 if svc["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
