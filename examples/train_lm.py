"""End-to-end LM training: the paper's 124M GPT-2 benchmark, with fault
tolerance (checkpoint/restart), stateless data, and sharded state.

Presets:
    smoke (default) — reduced 0.1M-param config, 120 steps: finishes on CPU
    full            — the real 124M config, a few hundred steps: the paper's
                      "LLM training" workload (run it on a real machine)

    PYTHONPATH=src python examples/train_lm.py [--preset full] [--steps N]
"""

import argparse

from repro.launch.train import TrainJob, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.preset == "full":
        job = TrainJob(arch="gpt2-124m", smoke=False,
                       steps=args.steps or 300, batch=8, seq=512,
                       remat="full", microbatches=2,
                       ckpt_dir=args.ckpt_dir, ckpt_every=50)
    else:
        job = TrainJob(arch="gpt2-124m", smoke=True,
                       steps=args.steps or 120, batch=8, seq=64,
                       ckpt_dir=args.ckpt_dir, ckpt_every=40)

    out = train(job)
    hist = out["history"]
    print(f"\n{'step':>6s} {'loss':>8s} {'grad_norm':>9s} {'lr':>9s}")
    for m in hist:
        print(f"{m['step']:6d} {m['loss']:8.4f} {m['grad_norm']:9.3f} {m['lr']:9.2e}")
    first, last = hist[0], hist[-1]
    print(f"\nloss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"({out['restarts']} restarts, "
          f"{out['straggler_events']} straggler events)")
    assert last["loss"] < first["loss"], "training did not reduce loss"


if __name__ == "__main__":
    main()
