"""Qwen3-32B — dense, GQA kv=8, qk-norm.

[hf:Qwen/Qwen3-8B family; hf]  64L, d_model=5120, 64H (GQA kv=8),
d_ff=25600, vocab=151936.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    qk_norm=True,
    param_dtype="float32",
    compute_dtype="float32",
)
