"""Speculative decoding contract (``repro.serve.speculative``).

The subsystem's one promise: speculation changes STEP COUNTS, never
BYTES.  Draft proposals are sampled from the same canonical per-request
PRNG streams the target verifies with, so at temperature 0 (and at any
temperature when draft == target) the served token streams are
bit-identical to the non-speculative continuous engine — what changes
is how many fused target steps the trace costs.

* Self-speculation (draft == target, f32) accepts EVERY proposal and
  spends strictly fewer fused target steps.
* An adversarial draft (independently initialized weights) gets its
  proposals rejected; rejection rewinds slot positions, decrefs the
  over-allocated tail blocks, and restores SSM/conv state via replay —
  with ``BlockPool.check_invariants()`` clean after every fused step
  and the streams still byte-identical, on all six serve architectures.
* Speculation composes with prefix sharing (COW) and int8 quantized
  paging without perturbing their streams.
* The ledger forks ``+spec<k>`` so the (by-design) lower step counters
  never gate against the non-speculative trajectory; the scenario
  matrix grows a ``speculate`` axis whose cells are checked against a
  spec-off golden twin.
"""

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.perf.ledger import metrics_from_scenario, metrics_from_serving
from repro.scenarios.matrix import (
    ArrivalSpec, EosSpec, MatrixSpec, PromptSpec,
)
from repro.scenarios.runner import run_cell
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod

SERVE_ARCHS = (
    "gpt2-124m", "qwen3-1.7b", "mamba2-370m", "deepseek-v2-lite-16b",
    "deepseek-moe-16b", "jamba-1.5-large-398b",
)

_MODELS = {}


def _model(arch, init_seed=0):
    key = (arch, init_seed)
    if key not in _MODELS:
        cfg = configs.get_smoke_config(arch)
        _MODELS[key] = (
            cfg, steps_mod.init_model(jax.random.PRNGKey(init_seed), cfg)
        )
    return _MODELS[key]


def _traffic(cfg, n=4, seed=17, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab,
                                    size=int(rng.integers(3, 9)))
                .astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _invariant_hook(record):
    """Step hook asserting the block pool invariants after every fused
    step of a speculative drain (rewinds included).
    ``check_invariants`` raises on violation; a recorded entry means one
    clean check actually ran."""
    def hook(eng, busy):
        if eng._live is not None:
            eng._live["pool"].check_invariants()
            record.append(True)
        return False
    return hook


def _serve(arch, *, spec_k=0, draft=None, hook=None, n=4, max_new=8,
           **eng_kw):
    cfg, params = _model(arch)
    kw = dict(eng_kw)
    if spec_k:
        dcfg, dparams = draft
        kw.update(spec_k=spec_k, draft_cfg=dcfg, draft_params=dparams)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, block_size=8,
                      scheduler="continuous", **kw)
    if hook is not None:
        eng.add_step_hook(hook)
    for r in _traffic(cfg, n=n, max_new=max_new):
        eng.submit(r)
    eng.run_until_drained()
    return eng


def _streams(eng):
    return {uid: r.generated for uid, r in eng.completed.items()}


# ---------------------------------------------------------------------------
# self-speculation: full acceptance, strictly fewer target steps
# ---------------------------------------------------------------------------


def test_self_spec_accepts_everything_and_saves_steps():
    base = _serve("gpt2-124m")
    spec = _serve("gpt2-124m", spec_k=4, draft=_model("gpt2-124m"))
    assert _streams(spec) == _streams(base)
    s = spec.stats()
    assert s["acceptance_rate"] == 1.0, s
    assert s["rejected_tokens"] == 0
    assert s["accepted_tokens"] == s["drafted_tokens"] > 0
    assert s["spec_k"] == 4
    assert s["draft_steps"] > 0
    # the headline: verification amortizes decode — strictly fewer fused
    # target steps for the same trace
    assert s["target_steps"] < base.stats()["fused_steps"], (
        s["target_steps"], base.stats()["fused_steps"])
    assert s["target_steps"] == s["fused_steps"]


def test_self_spec_bit_identical_at_temperature():
    """Draft and target share the canonical sampling streams, so
    self-speculation is exact at temperature > 0 too."""
    kw = dict(temperature=0.8, top_k=10, sample_seed=42)
    base = _serve("gpt2-124m", **kw)
    spec = _serve("gpt2-124m", spec_k=4, draft=_model("gpt2-124m"), **kw)
    assert _streams(spec) == _streams(base)
    assert spec.stats()["acceptance_rate"] == 1.0


# ---------------------------------------------------------------------------
# adversarial draft: rejection-heavy, streams unchanged, pool clean
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_adversarial_draft_streams_identical_every_arch(arch):
    """An independently-initialized gpt2 draft proposes garbage; the
    verifier rejects, rewinds positions, decrefs tail blocks (and
    replays SSM/conv state on the stateful paths) — and the streams
    stay byte-identical to the non-speculative engine on dense, GQA,
    MLA, MoE, SSM and hybrid serve paths, with the pool invariants
    clean after every step."""
    base = _serve(arch, max_new=6)
    checks = []
    adv = _serve(arch, spec_k=3, draft=_model("gpt2-124m", init_seed=123),
                 hook=_invariant_hook(checks), max_new=6)
    assert _streams(adv) == _streams(base), arch
    s = adv.stats()
    assert s["drafted_tokens"] > 0
    assert s["rejected_tokens"] > 0, (
        f"{arch}: an adversarial draft must actually get rejected")
    assert s["acceptance_rate"] < 1.0
    assert checks and all(checks), f"{arch}: pool invariants violated"


def test_rejection_rewind_frees_tail_blocks():
    """After a rejection-heavy drain no speculative over-allocation
    leaks: every pool block is released once the queue drains (the
    engine absorbs the pool on drain, so leaks would trip the hook's
    invariant checks and the final accounting)."""
    checks = []
    adv = _serve("gpt2-124m", spec_k=4,
                 draft=_model("gpt2-124m", init_seed=123),
                 hook=_invariant_hook(checks))
    s = adv.stats()
    assert s["rejected_tokens"] > 0
    assert checks and all(checks)
    # drained engine: no live batch, all requests completed
    assert adv._live is None
    assert len(adv.completed) == 4


# ---------------------------------------------------------------------------
# composition: quantized paging + prefix sharing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_dtype,share", [("int8", True), ("bf16", False)])
def test_spec_composes_with_quantized_sharing(kv_dtype, share):
    """Speculation on an int8/bf16 paged pool with COW prefix sharing:
    streams match the spec-off engine under the SAME pool config (the
    twin isolates speculation; quantization drift is its own axis)."""
    kw = dict(kv_dtype=kv_dtype, share_prefixes=share)
    base = _serve("gpt2-124m", **kw)
    checks = []
    spec = _serve("gpt2-124m", spec_k=4, draft=_model("gpt2-124m"),
                  hook=_invariant_hook(checks), **kw)
    assert _streams(spec) == _streams(base)
    s = spec.stats()
    assert s["drafted_tokens"] > 0
    assert checks and all(checks)
    if share:
        assert s["share_prefixes"] is True


# ---------------------------------------------------------------------------
# validation + stats surface
# ---------------------------------------------------------------------------


def test_spec_requires_draft_and_continuous():
    cfg, params = _model("gpt2-124m")
    dcfg, dparams = _model("gpt2-124m")
    with pytest.raises(ValueError, match="requires a draft"):
        ServeEngine(cfg, params, max_batch=2, max_len=64, spec_k=4)
    with pytest.raises(ValueError, match="spec_k must be >= 0"):
        ServeEngine(cfg, params, max_batch=2, max_len=64, spec_k=-1)
    with pytest.raises(ValueError, match="continuous"):
        ServeEngine(cfg, params, max_batch=2, max_len=64, scheduler="wave",
                    spec_k=4, draft_cfg=dcfg, draft_params=dparams)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(cfg, params, max_batch=2, max_len=64, prefill_chunk=4,
                    spec_k=4, draft_cfg=dcfg, draft_params=dparams)
    with pytest.raises(ValueError, match="spec_k >= 1"):
        ServeEngine(cfg, params, max_batch=2, max_len=64,
                    draft_cfg=dcfg, draft_params=dparams)


def test_stateful_draft_rejected():
    """The draft runs a plain (non-paged-state) prefill loop, so SSM /
    hybrid drafts are refused up front, not mid-drain."""
    cfg, params = _model("gpt2-124m")
    scfg, sparams = _model("mamba2-370m")
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, max_batch=2, max_len=64,
                    spec_k=4, draft_cfg=scfg, draft_params=sparams)


# ---------------------------------------------------------------------------
# ledger: the +spec<k> fork
# ---------------------------------------------------------------------------


def test_ledger_forks_spec_key_with_counters():
    spec = _serve("gpt2-124m", spec_k=4, draft=_model("gpt2-124m"))
    report = {"kind": "serve_report", "arch": "gpt2-124m",
              "scheduler": "continuous", "spec_k": 4,
              "stats": spec.stats(), "requests": []}
    (key, row), = metrics_from_serving(report).items()
    assert key == "serve/gpt2-124m@continuous+spec4"
    for name in ("spec_k", "drafted_tokens", "accepted_tokens",
                 "rejected_tokens", "draft_steps", "target_steps",
                 "acceptance_rate"):
        assert name in row, name
    assert row["acceptance_rate"] == 1.0
    assert isinstance(row["drafted_tokens"], int)
    # spec-off reports keep the unforked key and zero counters
    base = _serve("gpt2-124m")
    report0 = {"kind": "serve_report", "arch": "gpt2-124m",
               "scheduler": "continuous", "spec_k": 0,
               "stats": base.stats(), "requests": []}
    (key0, row0), = metrics_from_serving(report0).items()
    assert key0 == "serve/gpt2-124m@continuous"
    assert row0["drafted_tokens"] == 0 and row0["acceptance_rate"] == 0.0


# ---------------------------------------------------------------------------
# scenario matrix: the speculate axis and its spec-off golden twin
# ---------------------------------------------------------------------------


def _spec_matrix(**over):
    kw = dict(
        arrivals=[ArrivalSpec(kind="poisson", rate=0.5)],
        prompts=[PromptSpec(kind="uniform", lo=4, hi=10)],
        eos=[EosSpec(p_early=0.1)],
        schedulers=["continuous"],
        archs=["gpt2-124m"],
        faults=["none"],
        speculate=[4],
        requests=4,
        max_new=4,
        max_batch=2,
        max_len=64,
        block_size=8,
    )
    kw.update(over)
    return MatrixSpec(**kw)


def test_speculate_axis_expansion():
    spec = _spec_matrix(speculate=[0, 4])
    cells = spec.cells()
    ks = sorted(c.spec_k for c in cells)
    assert ks == [0, 4]
    on, = [c for c in cells if c.spec_k == 4]
    off, = [c for c in cells if c.spec_k == 0]
    assert on.cell_id.endswith("/spec4")
    assert "spec" not in off.cell_id
    # speculation is outside the traffic key: both cells replay the
    # exact same seeded request trace
    assert on.traffic_key == off.traffic_key
    twin = on.spec_twin()
    assert twin.spec_k == 0 and twin.fault == "none"
    # wave cells never speculate
    assert all(c.spec_k == 0
               for c in _spec_matrix(schedulers=["wave"],
                                     speculate=[0, 4]).cells())


def test_spec_cell_matches_spec_off_twin():
    cell, = _spec_matrix().cells()
    r = run_cell(cell)
    assert r.error == ""
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["spec_k"] == 4
    assert r.stats["drafted_tokens"] > 0
    assert r.stats["acceptance_rate"] == 1.0  # self-draft in the runner
    rep = r.report()
    assert rep["spec_k"] == 4
    (key, row), = metrics_from_scenario(rep).items()
    assert key.endswith("/spec4")
    assert row["acceptance_rate"] == 1.0
    assert row["golden_ok"] is True


def test_spec_cell_survives_preemption():
    """Preempting mid-speculation must rewind AND replay cleanly: the
    faulted spec cell still matches its fault-free golden twin and its
    spec-off twin."""
    cell, = _spec_matrix(faults=["preempt"]).cells()
    r = run_cell(cell)
    assert r.error == ""
    assert r.golden_checked and r.golden_ok, r.golden_diffs
    assert r.stats["preemptions"] >= 1
    assert r.stats["drafted_tokens"] > 0


# ---------------------------------------------------------------------------
# adaptive draft width: per-slot spec_k from the trailing acceptance EMA
# ---------------------------------------------------------------------------


def test_adaptive_width_streams_identical_drafts_fewer():
    """Against an adversarial (rejection-heavy) draft, the adaptive
    engine shrinks per-slot draft width toward plain decode — strictly
    fewer drafted tokens than fixed width — while serving the exact
    same streams (width changes how FAR we draft, never what
    verification accepts)."""
    base = _serve("gpt2-124m", max_new=8)
    fixed = _serve("gpt2-124m", spec_k=3,
                   draft=_model("gpt2-124m", init_seed=123), max_new=8)
    adapt = _serve("gpt2-124m", spec_k=3,
                   draft=_model("gpt2-124m", init_seed=123),
                   spec_adaptive=True, max_new=8)
    assert _streams(adapt) == _streams(base)
    assert _streams(adapt) == _streams(fixed)
    sf, sa = fixed.stats(), adapt.stats()
    assert sf["acceptance_rate"] < 1.0, "draft must actually be adversarial"
    assert 0 < sa["drafted_tokens"] < sf["drafted_tokens"], (
        "adaptive width must burn strictly fewer drafted lanes")
    assert sa["spec_adaptive"] is True and sf["spec_adaptive"] is False


def test_adaptive_width_keeps_full_width_on_self_draft():
    """Self-speculation accepts everything, so the EMA stays at 1.0 and
    the adaptive engine drafts exactly like the fixed-width one."""
    fixed = _serve("gpt2-124m", spec_k=3, draft=_model("gpt2-124m"))
    adapt = _serve("gpt2-124m", spec_k=3, draft=_model("gpt2-124m"),
                   spec_adaptive=True)
    assert _streams(adapt) == _streams(fixed)
    assert adapt.stats()["acceptance_rate"] == 1.0
    assert adapt.stats()["drafted_tokens"] == fixed.stats()["drafted_tokens"]


def test_adaptive_ema_clamps_and_recovers():
    """Width algebra: EMA folds accept ratios, clamps to [0, k], and a
    collapsed slot re-probes via the additive recovery schedule."""
    eng = _serve("gpt2-124m", spec_k=4, draft=_model("gpt2-124m"),
                 spec_adaptive=True, n=1, max_new=2)
    spec = eng._spec
    uid = 999
    assert spec._draft_width(uid) == 4  # fresh slot: full width
    for _ in range(8):  # hammer with total rejection
        spec._note_accept(uid, 0, 4)
    assert spec._accept_ema[uid] < 0.1
    w = spec._draft_width(uid)
    assert w == 0, "collapsed EMA must fall back to plain decode"
    # the zero-width probe bumps the EMA back up until width recovers
    for _ in range(32):
        if spec._draft_width(uid) > 0:
            break
    assert spec._draft_width(uid) >= 1, "recovery schedule must re-probe"
    # and the width never leaves [0, k]
    spec._accept_ema[uid] = 5.0
    assert spec._draft_width(uid) == 4


def test_adaptive_requires_speculation():
    cfg, params = _model("gpt2-124m")
    with pytest.raises(ValueError, match="spec_adaptive"):
        ServeEngine(cfg, params, max_batch=2, max_len=64, block_size=8,
                    scheduler="continuous", spec_adaptive=True)
