"""SpMV kernel call surface (served by the kernel registry) + the
issue-count model.

``issue_counts`` is the INST_RETIRED analogue: how many (8x128) vector tile
issues each variant needs.  Predicated (SVE/VLA-style) SpMV issues
ceil(nnz/lane) per row; fixed-width issues ceil(width/lane) always — their
ratio is the paper's Fig. 3a SpMV result (1.99x vs 1.0x).
"""

from __future__ import annotations

import math

import numpy as np

from repro.kernels.registry import (
    SPMV as spmv,
    SPMV_FIXED as spmv_padded,
)

__all__ = ["spmv", "spmv_padded", "issue_counts", "flops_bytes"]


def issue_counts(row_nnz, width: int, lane: int = 128) -> dict:
    """Vector-issue counts (INST_RETIRED analogue) for the two variants."""
    nnz = np.asarray(row_nnz)
    predicated = int(np.ceil(np.maximum(nnz, 1) / lane).sum())
    fixed = int(nnz.size * math.ceil(width / lane))
    scalar = int(np.maximum(nnz, 1).sum())  # 1 element / instruction
    return {
        "scalar": scalar,
        "predicated": predicated,
        "fixed_width": fixed,
        "r_ins_predicated": scalar / predicated,
        "r_ins_fixed": scalar / fixed,
    }


def flops_bytes(row_nnz, repeat: int = 1, dtype_bytes: int = 4) -> dict:
    """Analytic roofline terms for the synthetic benchmark (paper Sec. 3.2):
    per nonzero: 2*repeat FLOPs; traffic: val + colidx + gathered x."""
    nnz = float(np.asarray(row_nnz).sum())
    return {
        "flops": 2.0 * repeat * nnz,
        "bytes": nnz * (dtype_bytes + 4 + dtype_bytes),
        "gather_bytes": nnz * dtype_bytes,
        "ai": 2.0 * repeat * nnz / (nnz * (dtype_bytes + 4 + dtype_bytes)),
    }
