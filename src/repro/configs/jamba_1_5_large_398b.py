"""Jamba-1.5-Large (398B) — hybrid Mamba+attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf]  72L, d_model=8192, 64H (GQA kv=8), d_ff=24576,
vocab=65536.  Superblock = 8 layers with attention at index 4 (as in the
Jamba paper) and MoE replacing the dense MLP on every other layer.
Adaptation note (DESIGN.md §4): Jamba's Mamba-1 mixers are implemented as
Mamba-2/SSD chunked scans (TPU dual form); chunk=128 bounds the intra-chunk
score tensor at d_model=8192.
"""

from repro.configs.base import LayerKind, MoEConfig, ModelConfig, SSMConfig

M, A = LayerKind.MAMBA, LayerKind.ATTN

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    layer_pattern=(M, M, M, M, A, M, M, M),
    moe=MoEConfig(n_routed=16, top_k=2, d_ff_expert=24576, moe_every=2, moe_offset=1),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128),
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    layer_pattern=(M, M, M, M, A, M, M, M),
    moe=MoEConfig(n_routed=4, top_k=2, d_ff_expert=128, moe_every=2, moe_offset=1),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
    param_dtype="float32",
    compute_dtype="float32",
)
