"""The paper's methodology as a user-facing tool: point it at ANY jitted
JAX function and get the full SVE-style vectorization report — validated
counters, VB / R_ins, adapted roofline placement, and the Fig. 8 decision
tree — for both the Grace-class CPU model and the TPU target.

    PYTHONPATH=src python examples/vectorization_report.py
"""

import jax
import jax.numpy as jnp

from repro.core import hw
from repro.core.counters import events_from_compiled
from repro.core.decision_tree import classify
from repro.core.metrics import VectorizationReport
from repro.core.profiler import Profiler
from repro.core.roofline import adapted_roofline


def analyze(name, fn, args, dtype="fp32", chips=(hw.GRACE_CORE, hw.TPU_V5E)):
    """Compile fn, extract artifact events, classify on each chip model."""
    compiled = jax.jit(fn).lower(*args).compile()
    ev = events_from_compiled(compiled, n_devices=1)

    prof = Profiler()
    prof.configure_measure()
    prof.start_measure()
    jax.block_until_ready(jax.jit(fn)(*args))
    prof.stop_measure()
    prof.record(name, ev)

    print(f"\n### {name}")
    print(f"  flops={ev.flops:.3e}  traffic={ev.bytes_accessed:.3e}B  "
          f"gather={ev.gather_bytes:.3e}B  vec_frac={ev.vectorizable_fraction:.2%} "
          f"mxu_share={ev.mxu_fraction:.2%}")
    print(f"  counter validation: structural flops {ev.flops:.3e} vs "
          f"raw cost_analysis {ev.xla_raw_flops:.3e} "
          f"(scan trip counts: {ev.while_trip_counts or 'none'})")
    for chip in chips:
        rl = adapted_roofline(chip, dtype)
        rep = VectorizationReport(
            name=name, dtype=dtype,
            flops=ev.flops, hbm_bytes=ev.bytes_accessed,
            gather_bytes=ev.gather_bytes,
            ins_scalar=ev.flops / 2,
            ins_vec=ev.flops / 2 / rl.vb,
            vectorizable_fraction=ev.vectorizable_fraction,
        )
        d = classify(rep, chip)
        print(f"  [{chip.name:12s}] AI={rep.ai:8.3g}  knee={rl.ai_irr:6.3g}  "
              f"VB={rl.vb:4.0f}  Class {int(d.perf_class)} "
              f"({d.perf_class.describe()})")


def main():
    n = 512
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)

    analyze("gemm-512", lambda x, y: x @ y, (a, b))

    analyze("stream-triad", lambda x, y: x + 3.0 * y, (a, b))

    # pointer chasing: the SpMV pattern
    idx = jax.random.randint(jax.random.PRNGKey(2), (n * n,), 0, n * n)
    flat = a.reshape(-1)
    analyze("gather-reduce", lambda x, i: jnp.take(x, i).sum(), (flat, idx))

    # scanned layers: exercises the while-aware counter path
    def scanned(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y
    analyze("scan-8-layers", scanned, (a,))

    # FFT: not MXU-vectorizable (the paper's FFTW Class-1 case)
    analyze("fft2d", lambda x, _: jnp.abs(jnp.fft.fft2(x)), (a, b))


if __name__ == "__main__":
    main()
