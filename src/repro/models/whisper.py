"""Whisper-style encoder-decoder backbone (conv frontend is a stub).

``input_specs`` supplies *post-conv* audio frames (B, S_enc, d_model) — the
two stride-2 convs of the real frontend are stubbed per the assignment, so
S_enc = seq_len // 4.  The decoder is a standard causal transformer with
cross-attention into the encoder output.  Self-attention uses RoPE (a
documented modernization; Whisper's learned positions change no cost term).
Cross-attention is position-free, as in the original.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers


# --------------------------------------------------------------------------


def _init_cross_attn(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], d, h * hd, dtype),
        "wk": layers.dense_init(ks[1], d, kv * hd, dtype),
        "wv": layers.dense_init(ks[2], d, kv * hd, dtype),
        "wo": layers.dense_init(ks[3], h * hd, d, dtype),
    }


def _cross_kv(p, cfg, enc_out):
    B, Se, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = layers.dense(p["wk"], enc_out).reshape(B, Se, kv, hd)
    v = layers.dense(p["wv"], enc_out).reshape(B, Se, kv, hd)
    return k, v


def _cross_attend(p, cfg, x, k, v):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = layers.dense(p["wq"], x).reshape(B, S, kv, h // kv, hd)
    if S == 1:
        # decode: direct attention — every op reduces over the (sharded)
        # encoder seq axis, so GSPMD lowers to tiny stat all-reduces; the
        # chunked flash path's tile reshapes would gather the cross-KV
        # cache per layer (§Perf, whisper decode cell)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        pmax = s.max(axis=-1, keepdims=True)
        pexp = jnp.exp(s - pmax)
        ctx = jnp.einsum("bkgqs,bskd->bkgqd", pexp.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = (ctx / pexp.sum(-1)[..., None]).astype(x.dtype)
        out = out.transpose(0, 3, 1, 2, 4)  # (B, 1, kv, g, hd)
    else:
        out = attention.flash_attention(q, k, v, causal=False)
    return layers.dense(p["wo"], out.reshape(B, S, h * hd))


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    norm_init, _ = layers.make_norm(cfg)
    return {
        "norm1": norm_init(dtype),
        "attn": attention.init_attention(k1, cfg, dtype),
        "norm2": norm_init(dtype),
        "ffn": layers.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    norm_init, _ = layers.make_norm(cfg)
    return {
        "norm1": norm_init(dtype),
        "attn": attention.init_attention(k1, cfg, dtype),
        "norm_x": norm_init(dtype),
        "xattn": _init_cross_attn(k2, cfg, dtype),
        "norm2": norm_init(dtype),
        "ffn": layers.swiglu_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_whisper(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
    n_dec = cfg.n_layers - cfg.enc_layers
    norm_init, _ = layers.make_norm(cfg)
    return {
        "embed": layers.embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.enc_layers)
        ),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(
            jax.random.split(k_dec, n_dec)
        ),
        "enc_norm": norm_init(dtype),
        "final_norm": norm_init(dtype),
        "lm_head": layers.dense_init(k_head, cfg.d_model, cfg.vocab_padded, dtype),
    }


# --------------------------------------------------------------------------


def encode(params, cfg, enc_frames: jax.Array, *, remat: str = "none") -> jax.Array:
    from repro.distributed import context as mesh_ctx

    plan = mesh_ctx.current()
    x = enc_frames.astype(jnp.dtype(cfg.compute_dtype))
    B, Se, _ = x.shape
    positions = jnp.arange(Se, dtype=jnp.int32)[None, :].repeat(B, 0)
    _, norm_fn = layers.make_norm(cfg)

    def body(x, p):
        h = norm_fn(p["norm1"], x)
        x = mesh_ctx.shard_seq(
            x + attention.attention_full(p["attn"], cfg, h, positions, causal=False),
            plan)
        h = norm_fn(p["norm2"], x)
        return mesh_ctx.shard_seq(x + layers.swiglu(p["ffn"], h), plan), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm_fn(params["enc_norm"], x)


def forward(
    params, cfg: ModelConfig, enc_frames: jax.Array, tokens: jax.Array,
    *, remat: str = "none",
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced logits: (B, S_dec, V) fp32."""
    enc_out = encode(params, cfg, enc_frames, remat=remat)
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    B, Sd, _ = x.shape
    positions = jnp.arange(Sd, dtype=jnp.int32)[None, :].repeat(B, 0)
    _, norm_fn = layers.make_norm(cfg)

    def body(x, p):
        from repro.distributed import context as mesh_ctx

        plan = mesh_ctx.current()
        h = norm_fn(p["norm1"], x)
        x = mesh_ctx.shard_seq(
            x + attention.attention_full(p["attn"], cfg, h, positions, causal=True),
            plan)
        h = norm_fn(p["norm_x"], x)
        k, v = _cross_kv(p["xattn"], cfg, enc_out)
        x = mesh_ctx.shard_seq(x + _cross_attend(p["xattn"], cfg, h, k, v), plan)
        h = norm_fn(p["norm2"], x)
        return mesh_ctx.shard_seq(x + layers.swiglu(p["ffn"], h), plan), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm_fn(params["final_norm"], x)
    logits = layers.dense(params["lm_head"], x).astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def init_dec_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    n_dec = cfg.n_layers - cfg.enc_layers
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((n_dec, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((n_dec, batch, max_len, kv, hd), dtype),
        "cross_k": jnp.zeros((n_dec, batch, enc_len, kv, hd), dtype),
        "cross_v": jnp.zeros((n_dec, batch, enc_len, kv, hd), dtype),
    }


def prefill(params, cfg, enc_frames, tokens, *, remat: str = "none"):
    """Encode audio + consume prompt tokens; build decoder cache."""
    enc_out = encode(params, cfg, enc_frames, remat=remat)
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    B, Sd, _ = x.shape
    positions = jnp.arange(Sd, dtype=jnp.int32)[None, :].repeat(B, 0)
    _, norm_fn = layers.make_norm(cfg)

    def body(x, p):
        h = norm_fn(p["norm1"], x)
        att, kv_cache = attention.attention_full_with_cache(p["attn"], cfg, h, positions)
        x = x + att
        h = norm_fn(p["norm_x"], x)
        ck, cv = _cross_kv(p["xattn"], cfg, enc_out)
        x = x + _cross_attend(p["xattn"], cfg, h, ck, cv)
        h = norm_fn(p["norm2"], x)
        return x + layers.swiglu(p["ffn"], h), {
            "k": kv_cache["k"], "v": kv_cache["v"], "cross_k": ck, "cross_v": cv,
        }

    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm_fn(params["final_norm"], x[:, -1:, :])
    logits = layers.dense(params["lm_head"], x).astype(jnp.float32)
    caches["pos"] = jnp.full((), Sd, jnp.int32)
    return logits, caches


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache):
    """One decoder token over cached self-KV + precomputed cross-KV."""
    pos = cache["pos"]
    x = layers.embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    _, norm_fn = layers.make_norm(cfg)

    def body(x, inp):
        p, k, v, ck, cv = inp
        h = norm_fn(p["norm1"], x)
        att, k_new, v_new = attention.attention_decode(p["attn"], cfg, h, k, v, pos)
        x = x + att
        h = norm_fn(p["norm_x"], x)
        x = x + _cross_attend(p["xattn"], cfg, h, ck, cv)
        h = norm_fn(p["norm2"], x)
        return x + layers.swiglu(p["ffn"], h), (k_new, v_new)

    x, (k_news, v_news) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]),
    )
    x = norm_fn(params["final_norm"], x)
    logits = layers.dense(params["lm_head"], x).astype(jnp.float32)
    new_cache = dict(cache)
    # one top-level commit of all layers' new-token KV slices
    new_cache.update({
        "pos": pos + 1,
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k_news.astype(cache["k"].dtype), (0, 0, pos, 0, 0)
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v_news.astype(cache["v"].dtype), (0, 0, pos, 0, 0)
        ),
    })
    return logits, new_cache
