"""Speculative decoding over the paged KV cache.

A small *draft* model proposes up to ``k`` tokens per busy slot; the
*target* model then scores all of them in ONE fused
:func:`~repro.models.transformer.prefill_step_paged` call — the same
scan cell chunked prefill uses, with per-slot ragged ``valid_len``, so
verification is bit-exact against token-by-token decode.  The longest
proposal prefix that matches the target's own (canonical-stream, see
:mod:`repro.serve.sampling`) choices is accepted, plus the target's one
correction token; the rejected suffix is undone by rewinding
``positions[slot]`` and decref'ing now-stale tail blocks through the
:class:`~repro.serve.block_pool.BlockPool`.

This is the paper's Eq. 1 economics one level up: the k-wide
verification step is a vector issue, the drafted positions are its
lanes, and :func:`repro.core.metrics.acceptance_rate` is the active-lane
fraction — rejected drafts burn issue slots exactly like predicated-out
SVE lanes.

Why the streams stay bit-identical to the non-speculative engine at any
temperature: both the draft proposals and the target verification read
the SAME per-``(request, generation_index)`` PRNG streams, and the
target's choice at index ``i`` is computed from canonical logits
whenever the prefix through ``i-1`` was accepted.  Accepted tokens are
therefore exactly the tokens the plain engine would have emitted, and a
rejection merely defers index ``i`` to the next step, where the same
key meets the same canonical logits again.  Speculation changes only
how many fused target steps the stream costs, never its content.

Rewind correctness, per cache kind:

* **Attention blocks** — rows past the rewound position are dead weight
  hidden by the causal position mask; the next verification window
  overwrites them before they can be attended (the chunked-prefill
  argument).  Blocks that lie ENTIRELY past the next write position are
  decref'd back to the pool, and ``note_generated_write`` trimming at
  write time already guarantees no prefix-registry key can alias a
  speculated row.
* **SSM / conv state** — accumulated by every scanned token and NOT
  position-masked, so it cannot be rewound by masking.  The decoder
  snapshots the per-slot state leaves (by reference: jax arrays are
  immutable) before each verification, and on any rejection restores
  the snapshot for the rejected slots and replays just their accepted
  tokens through one extra fused call.  The replay starts from the
  identical pre-verification state and feeds the identical tokens, so
  the recomputed state is bitwise what sequential decode would have
  produced.

The draft model must be attention-only (no recurrent state): its paged
f32 cache shares the target's block tables, pool, and copy-on-write
schedule, so draft-side history management costs nothing beyond the
second cache.
"""

from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerKind, ModelConfig
from repro.models import transformer
from repro.serve.block_pool import BlockPool
from repro.serve.sampling import SlotSampler


@functools.lru_cache(maxsize=None)
def _jit_draft_prefill(cfg: ModelConfig, block_size: int):
    """Draft-side fused step: always an f32 paged cache (the draft is
    small — quantizing its cache buys nothing and would perturb
    proposals for zero accounting benefit)."""
    return jax.jit(
        lambda p, t, c, pos, bt, lens: transformer.prefill_step_paged(
            p, cfg, t, c, pos, bt, lens, block_size=block_size,
            kv_dtype="f32",
        )
    )


@functools.lru_cache(maxsize=1)
def _jit_restore_state():
    return jax.jit(transformer.restore_slot_state)


def _draft_param_shardings(params, mesh):
    """Megatron rules applied to the draft's params (same rule table as
    the target — the draft is a plain attention LM)."""
    from repro.distributed import sharding as shard_rules
    return shard_rules.serve_param_shardings(params, mesh)


class SpeculativeDecoder:
    """Draft model + verification drain for one :class:`ServeEngine`.

    Owns everything draft-side (config, params, compiled step, the
    proposal sampler) plus the speculative drain loop; the engine's own
    compiled steps, sampler, and accounting are reused through the
    ``eng`` handle passed to :meth:`drain`.
    """

    #: EMA weight for the trailing per-request acceptance rate; 0.5 adapts
    #: within a couple of verification windows (smoke traces are short)
    _ALPHA = 0.5
    #: additive re-probe rate for a stream whose width collapsed to 0 —
    #: a few plain decode steps later it drafts width >= 1 again, so a
    #: distribution shift is never locked out (deterministic, no RNG)
    _RECOVERY = 0.125

    def __init__(self, draft_cfg: ModelConfig, draft_params, k: int, *,
                 target_cfg: ModelConfig, block_size: int,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 adaptive: bool = False, mesh=None,
                 max_batch: Optional[int] = None,
                 max_len: Optional[int] = None):
        if k < 1:
            raise ValueError(f"spec_k must be >= 1, got {k}")
        if any(kind != LayerKind.ATTN for kind in draft_cfg.superblock):
            raise ValueError(
                "the draft model must be attention-only: recurrent "
                "(SSM/conv) draft state cannot share the rewind-by-"
                f"masking path, got superblock {draft_cfg.superblock}"
            )
        self.cfg = draft_cfg
        self.params = draft_params
        self.k = int(k)
        self.block_size = block_size
        self.adaptive = bool(adaptive)
        self.mesh = mesh
        # uid -> EMA of the trailing acceptance rate; absent = optimistic
        # 1.0 (first window drafts full width, like non-adaptive mode)
        self._accept_ema = {}
        # proposals must be valid token ids for BOTH models, and tokens
        # fed back into the draft are clamped to its vocab below
        self.shared_vocab = min(draft_cfg.vocab, target_cfg.vocab)
        self.sampler = SlotSampler(
            self.shared_vocab, temperature=temperature, top_k=top_k,
            seed=seed,
        )
        if mesh is None:
            self._prefill = _jit_draft_prefill(draft_cfg, block_size)
        else:
            # the draft's fused step gets the same explicit-sharding
            # treatment as the target's (attention-only cfg: only the
            # k/v head-split pool rules fire on its cache)
            from repro.serve.engine import _sharded_jits
            self._prefill = _sharded_jits(
                draft_cfg, int(max_batch), int(max_len), block_size,
                "f32", mesh,
            )["prefill"]
            self.params = jax.device_put(
                draft_params, _draft_param_shardings(draft_params, mesh)
            )
        self._restore = _jit_restore_state()

    def _draft_width(self, uid: int) -> int:
        """Per-slot draft width from the trailing acceptance EMA, clamped
        to [0, spec_k].  Non-adaptive engines always draft full width.

        A rejection-heavy stream shrinks toward 0 (plain decode — no
        drafted lanes burned), a well-predicted one grows back toward
        ``k``; a collapsed stream re-probes via the additive
        ``_RECOVERY`` schedule.  Width only changes how FAR we draft,
        never what verification accepts, so served streams are identical
        to the fixed-width engine's.
        """
        if not self.adaptive:
            return self.k
        ema = self._accept_ema.get(uid, 1.0)
        w = int(round(ema * self.k))
        if w <= 0:
            self._accept_ema[uid] = min(1.0, ema + self._RECOVERY)
        return max(0, min(self.k, w))

    def _note_accept(self, uid: int, accepted: int, drafted: int) -> None:
        """Fold one verification window's acceptance into the uid's EMA."""
        if not self.adaptive or drafted <= 0:
            return
        ema = self._accept_ema.get(uid, 1.0)
        self._accept_ema[uid] = (
            (1.0 - self._ALPHA) * ema + self._ALPHA * accepted / drafted
        )

    def _clamp(self, tokens: np.ndarray) -> np.ndarray:
        """Token ids the draft embeds must lie inside ITS vocab; target
        tokens past it are clamped (the draft's conditioning degrades,
        its proposals just get rejected more — correctness never depends
        on the draft's inputs)."""
        return np.minimum(tokens, self.cfg.vocab - 1)

    def warmup(self, eng) -> None:
        """Compile the draft's 1-wide fused step (called from
        :meth:`ServeEngine.warmup`, which warms the target side)."""
        B = eng.max_batch
        dcache = transformer.init_paged_cache(
            self.cfg, B, eng.max_len, self.block_size, "f32",
            mesh=eng.mesh,
        )
        out = self._prefill(
            self.params, jnp.zeros((B, 1), jnp.int32), dcache,
            jnp.zeros((B,), jnp.int32),
            jnp.zeros((B, eng.max_len // self.block_size), jnp.int32),
            jnp.zeros((B,), jnp.int32),
        )
        jax.block_until_ready(out[0])

    # -- the speculative continuous drain --------------------------------------

    def drain(self, eng, max_steps: Optional[int]) -> None:
        """Continuous drain where generation slots advance up to ``k+1``
        tokens per fused target step.

        Each iteration: (1) slots still consuming known tokens (prompt,
        or a preemption replay) are fed one known token, exactly like
        the plain continuous drain; (2) every *generating* slot gets up
        to ``k`` sequential draft proposals; (3) one fused target call
        verifies every slot's window at once (ragged ``lens``); (4) per
        slot, the accepted prefix plus the target's correction token are
        emitted and the rejected suffix is rewound.  The draft cache is
        kept in sync by feeding it every committed token: draft round 0
        covers each slot's current token, later rounds cover the
        proposals themselves.
        """
        # engine.py never imports this module at definition time (the
        # ServeEngine ctor imports it lazily), so this is one-directional
        from repro.serve.engine import _MAX_IDLE_SPINS

        _dev, _dev_tok = eng._dev, eng._dev_tok  # mesh-aware placement
        restore = eng._restore_state or self._restore
        B, bs, k = eng.max_batch, eng.block_size, self.k
        W = k + 1
        nb_slot = eng.max_len // bs
        cache = eng._new_cache()
        dcache = transformer.init_paged_cache(
            self.cfg, B, eng.max_len, bs, "f32", mesh=eng.mesh
        )
        positions = np.zeros(B, np.int32)
        block_tables = np.zeros((B, nb_slot), np.int32)  # 0 = null block
        pool = BlockPool(1 + B * nb_slot, bs,
                         share_prefixes=eng.share_prefixes)
        slot_req = [None] * B
        tokens = np.zeros((B, 1), np.int32)
        reset_mask = np.zeros(B, bool)
        eng._live = {
            "positions": positions, "block_tables": block_tables,
            "free": pool.free, "pool": pool, "slot_req": slot_req,
            "tokens": tokens,
        }
        idle_spins = 0

        try:
            while True:
                pending = eng._call_hooks(
                    busy=any(r is not None for r in slot_req)
                )
                for b in range(B):
                    if slot_req[b] is None and eng.queue:
                        r = eng.queue.popleft()
                        slot_req[b] = r
                        if r.started_s is None:
                            r.started_s = time.time()
                        positions[b] = 0
                        block_tables[b] = 0
                        tokens[b, 0] = r.prompt[0]
                        reset_mask[b] = True
                if all(r is None for r in slot_req):
                    if not pending:
                        break
                    idle_spins += 1  # hooks promise work; let them deliver
                    if idle_spins > _MAX_IDLE_SPINS:
                        raise RuntimeError(
                            "step hooks report pending work but never submit"
                        )
                    continue
                idle_spins = 0
                # occupancy bound: a verification step advances every busy
                # slot by >= 1 position, but stateful targets may spend one
                # extra replay call per rejected step — hence the factor 2
                budget = (max_steps if max_steps is not None
                          else 2 * eng._submitted_work + B)
                if eng.steps >= budget:
                    raise RuntimeError("serve loop did not drain")

                # -- plan: draft width per slot (0 = known-token feed or
                # nothing left to speculate on) -----------------------------
                spec_w = np.zeros(B, np.int32)
                uids_gen = list(slot_req)  # snapshot for stream indexing
                for b, r in enumerate(slot_req):
                    if r is None:
                        continue
                    t = int(positions[b])
                    n_rem = len(r.prompt) + len(r.generated) - t
                    if n_rem == 1:
                        # generating: draft as far as the token budget,
                        # the slot's cache, and (adaptive mode) the uid's
                        # trailing-acceptance width allow (the window
                        # writes through position t + spec_w, which must
                        # stay < max_len)
                        remaining = r.max_new_tokens - len(r.generated)
                        spec_w[b] = max(
                            0, min(self._draft_width(r.uid),
                                   remaining - 1, eng.max_len - 1 - t)
                        )
                any_spec = bool((spec_w > 0).any())

                # -- map blocks + copy-on-write for every position this
                # step writes (t .. t + spec_w[b]), in BOTH caches ----------
                for b, r in enumerate(slot_req):
                    if r is None:
                        continue
                    t = int(positions[b])
                    hi = t + int(spec_w[b])
                    for j in range(t // bs, hi // bs + 1):
                        if block_tables[b, j] == 0:
                            blk = pool.acquire(r.prompt, j)
                            block_tables[b, j] = blk
                            eng.block_history.setdefault(
                                r.uid, []
                            ).append(blk)
                    gen_from = max(t, len(r.prompt))
                    if gen_from <= hi:
                        for j in range(gen_from // bs, hi // bs + 1):
                            old = int(block_tables[b, j])
                            if pool.refcount_of(old) > 1:
                                new = pool.cow(old)
                                cache = eng._copy_block(
                                    cache, jnp.int32(old), jnp.int32(new)
                                )
                                dcache = eng._copy_block(
                                    dcache, jnp.int32(old), jnp.int32(new)
                                )
                                block_tables[b, j] = new
                                eng.block_history.setdefault(
                                    r.uid, []
                                ).append(new)
                            # speculated rows are generated rows: trim any
                            # registry key claiming them BEFORE they are
                            # written, so a rewound row can never alias a
                            # prefix-shared key
                            pool.note_generated_write(
                                int(block_tables[b, j]),
                                max(gen_from, j * bs) % bs,
                            )
                if eng._has_state and reset_mask.any():
                    cache = eng._reset_slots(cache, _dev(reset_mask))
                reset_mask[:] = False
                eng._note_busy(r is not None for r in slot_req)

                # -- draft phase: sequential 1-wide proposals ----------------
                # round 0 feeds every busy slot's current token (keeping the
                # draft cache in sync even during prompt consumption); round
                # i >= 1 feeds proposal d_i at position t + i for slots wide
                # enough — INCLUDING the final round that commits d_w's row
                # without proposing further, so on full acceptance the draft
                # cache is complete through t + w and the next step never
                # attends an unwritten row.  Proposals for index gi + i are
                # sampled from the same canonical stream the target
                # verifies against.
                drafts = np.zeros((B, k), np.int32)
                d_tokens = np.array(tokens)
                d_lens = np.zeros(B, np.int32)
                rounds = int(spec_w.max())  # proposals needed per slot max
                for i in range(rounds + 1):
                    d_lens[:] = 0
                    for b, r in enumerate(slot_req):
                        if r is None:
                            continue
                        if i == 0:
                            d_lens[b] = 1
                        elif int(spec_w[b]) >= i:
                            d_lens[b] = 1
                            d_tokens[b, 0] = drafts[b, i - 1]
                    dlogits, dcache = self._prefill(
                        self.params, _dev_tok(self._clamp(d_tokens)), dcache,
                        _dev(positions + i), _dev(block_tables),
                        _dev(d_lens),
                    )
                    eng.draft_steps += 1
                    if i < rounds:
                        di = self.sampler.select(
                            dlogits, uids_gen, offset=i
                        )
                        for b in range(B):
                            if int(spec_w[b]) > i:
                                drafts[b, i] = int(di[b, 0])

                # -- verification: one fused target call over every slot's
                # ragged window [x_t, d_1 .. d_{w_b}] -----------------------
                pos0 = positions.copy()
                if any_spec:
                    v_tokens = np.zeros((B, W), np.int32)
                    v_lens = np.zeros(B, np.int32)
                    for b, r in enumerate(slot_req):
                        if r is None:
                            continue
                        w_b = int(spec_w[b])
                        v_tokens[b, 0] = tokens[b, 0]
                        v_tokens[b, 1:1 + w_b] = drafts[b, :w_b]
                        v_lens[b] = 1 + w_b
                    snap = (transformer.slot_state(cache)
                            if eng._has_state else None)
                    logits, cache = eng._prefill_paged(
                        eng.params, _dev_tok(v_tokens), cache,
                        _dev(positions), _dev(block_tables), _dev(v_lens),
                    )
                    eng.steps += 1
                    # row i of slot b is the target's canonical choice for
                    # generation index gi + i — valid wherever the proposal
                    # prefix through i-1 matched
                    y = eng._sampler.select(logits, uids_gen)
                else:
                    logits, cache = eng._decode_paged(
                        eng.params, _dev_tok(tokens), cache,
                        _dev(positions), _dev(block_tables),
                    )
                    eng.steps += 1
                    y = eng._sampler.select(logits, uids_gen)

                # -- acceptance, emission, rewind ----------------------------
                replay_lens = np.zeros(B, np.int32)
                for b, r in enumerate(slot_req):
                    if r is None:
                        continue
                    t = int(positions[b])
                    w_b = int(spec_w[b])
                    if w_b == 0:
                        # plain continuous semantics: consume one known
                        # token or append the single selected one
                        positions[b] = t + 1
                        if t + 1 < len(r.prompt):
                            tokens[b, 0] = r.prompt[t + 1]
                            continue
                        gi = t + 1 - len(r.prompt)
                        if gi < len(r.generated):
                            # preemption replay: already served, feed back
                            tokens[b, 0] = r.generated[gi]
                            continue
                        tok = int(y[b, 0])
                        eng._note_first_token(r)
                        r.generated.append(tok)
                        tokens[b, 0] = tok
                        if (len(r.generated) >= r.max_new_tokens
                                or tok == r.eos_id):
                            self._release_slot(
                                b, slot_req, block_tables, positions,
                                tokens, pool, nb_slot, eng
                            )
                        continue
                    # longest proposal prefix matching the target's choices
                    a = 0
                    while a < w_b and int(drafts[b, a]) == int(y[b, a]):
                        a += 1
                    eng.drafted_tokens += w_b
                    eng.accepted_tokens += a
                    eng.rejected_tokens += w_b - a
                    self._note_accept(r.uid, a, w_b)
                    # emit the accepted prefix plus the correction token,
                    # stopping at EOS / budget exactly like 1-wide decode
                    emitted = 0
                    finished = False
                    for i in range(a + 1):
                        tok = int(y[b, i])
                        eng._note_first_token(r)
                        r.generated.append(tok)
                        emitted += 1
                        if (len(r.generated) >= r.max_new_tokens
                                or tok == r.eos_id):
                            finished = True
                            break
                    positions[b] = t + emitted
                    if finished:
                        self._release_slot(
                            b, slot_req, block_tables, positions, tokens,
                            pool, nb_slot, eng
                        )
                        continue
                    tokens[b, 0] = int(y[b, emitted - 1])
                    # rewind: blocks lying entirely past the next write
                    # position hold only rejected rows — return them (decref,
                    # never free: sharing may keep them alive elsewhere)
                    p = t + emitted
                    for j in range(p // bs + 1, (t + w_b) // bs + 1):
                        if block_tables[b, j] != 0:
                            pool.decref(int(block_tables[b, j]))
                            block_tables[b, j] = 0
                    if eng._has_state and emitted < w_b + 1:
                        replay_lens[b] = emitted

                # -- stateful rewind: restore pre-verification state for
                # rejected slots and replay their accepted tokens -----------
                if eng._has_state and any_spec and replay_lens.any():
                    mask = replay_lens > 0
                    cache = restore(cache, snap, _dev(mask))
                    _, cache = eng._prefill_paged(
                        eng.params, _dev_tok(v_tokens), cache,
                        _dev(pos0), _dev(block_tables), _dev(replay_lens),
                    )
                    eng.steps += 1
        finally:
            eng._absorb_pool(pool)
            eng._live = None

    @staticmethod
    def _release_slot(b, slot_req, block_tables, positions, tokens, pool,
                      nb_slot, eng) -> None:
        """Finish slot ``b``'s request and return its blocks (shared
        blocks survive under their other referents' refcounts)."""
        eng._finish(slot_req[b])
        for j in range(nb_slot):
            if block_tables[b, j] != 0:
                pool.decref(int(block_tables[b, j]))
        block_tables[b] = 0
        positions[b] = 0
        tokens[b, 0] = 0
        slot_req[b] = None
