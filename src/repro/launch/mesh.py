"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches see the real single CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host has (CPU tests): (n_dev/model, model)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch (ZeRO/data-parallel) dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
