"""Baseline resolution policies for the regression gate.

A gate is only as good as what it compares against.  Three policies:

* ``latest`` — the most recent run in the series (the CI cold/warm pair).
* ``pinned:<prefix>`` — an explicit anchor: a run-id prefix or a git SHA
  prefix.  This is how a known-good release becomes the yardstick.
* ``median:<K>`` — a synthetic run whose numeric metrics are the
  per-metric median of the last K runs.  Medians absorb the wall-clock
  noise a single baseline run would bake in (the paper's measured
  quantities are best-of-repeats for the same reason); non-numeric
  metrics (configs, pass/fail) take the most recent run's value.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Dict, Iterable, List, Optional

from repro.perf.ledger import BenchRun, Ledger


def _median_run(runs: List[BenchRun]) -> BenchRun:
    """Synthetic rolling-median BenchRun over ``runs`` (newest last)."""
    newest = runs[-1]
    metrics: Dict[str, Dict[str, Any]] = {}
    for key in newest.metrics:
        merged: Dict[str, Any] = {}
        for name, value in newest.metrics[key].items():
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                merged[name] = value
                continue
            window = [
                r.metrics[key][name]
                for r in runs
                if key in r.metrics and name in r.metrics[key]
                and isinstance(r.metrics[key][name], (int, float))
                and not isinstance(r.metrics[key][name], bool)
            ]
            merged[name] = statistics.median(window) if window else value
        metrics[key] = merged
    return dataclasses.replace(
        newest,
        run_id=f"median-{len(runs)}-of:{newest.run_id}",
        metrics=metrics,
        meta={**newest.meta, "synthetic": f"median:{len(runs)}"},
    )


def validate_policy(policy: str) -> str:
    """Parse-check a policy string without touching a ledger; returns it.

    Raises ValueError on malformed input — callers that run expensive work
    before gating (benchmarks.run) validate up front, and the CLIs use
    this as an argparse ``type`` so a typo exits 2 immediately.
    """
    if policy == "latest":
        return policy
    if policy.startswith("pinned:"):
        if not policy[len("pinned:"):]:
            raise ValueError("pinned: policy needs a run-id or git-SHA prefix")
        return policy
    if policy.startswith("median:"):
        try:
            k = int(policy[len("median:"):])
        except ValueError:
            raise ValueError(f"median: policy needs an integer K, got {policy!r}")
        if k < 1:
            raise ValueError(f"median:{k} — K must be >= 1")
        return policy
    raise ValueError(
        f"unknown baseline policy {policy!r}; "
        "expected latest | pinned:<prefix> | median:<K>"
    )


def resolve_baseline(
    ledger: Ledger,
    policy: str = "latest",
    *,
    series: Optional[str] = None,
    exclude: Iterable[str] = (),
) -> Optional[BenchRun]:
    """Resolve ``policy`` against the ledger; None when no run qualifies.

    ``exclude`` drops run ids from consideration — the gate passes the
    run under test here so a freshly recorded run never becomes its own
    baseline.  ``series`` restricts to one (chip, dtype) trajectory.

    ``latest`` and ``median:<K>`` consider only *healthy* runs (no
    ``meta["failed"]`` count): an aborted benchmark records a truncated
    wall time, and anchoring on it would fail the next healthy run
    spuriously.  ``pinned:`` is the operator's explicit choice and is
    never filtered.
    """
    validate_policy(policy)
    excluded = set(exclude)
    runs = [r for r in ledger.runs(series) if r.run_id not in excluded]
    if not runs:
        return None
    healthy = [r for r in runs if not r.meta.get("failed")]
    if policy == "latest":
        return healthy[-1] if healthy else None
    if policy.startswith("pinned:"):
        anchor = policy[len("pinned:"):]
        matches = [
            r for r in runs
            if r.run_id.startswith(anchor) or r.env.git_sha.startswith(anchor)
        ]
        return matches[-1] if matches else None
    k = int(policy[len("median:"):])
    return _median_run(healthy[-k:]) if healthy else None
