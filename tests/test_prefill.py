"""Chunked-prefill contract, kernel to engine.

Three layers, one invariant — chunking changes SCHEDULING, never bytes:

* kernel: ``flash_prefill_paged`` (causal online-softmax over a chunk,
  committing K/V through the paged block tables) matches the dense
  ``prefill_paged_ref`` oracle, commits pools bit-exactly, and ignores
  stale bytes past the chunk frontier (predication, Eq. 1).
* model: ``prefill_step_paged`` is a scan over the SAME per-token cell as
  ``decode_step_paged``, so a C-token chunk produces bit-identical logits
  AND bit-identical paged-cache bytes to C single-token steps — across
  every serve architecture (dense, GQA, MLA, MoE, SSM, hybrid).
* engine: chunked serving emits byte-identical token streams to the
  token-by-token scheduler in strictly fewer fused steps, and the
  deterministic step-clock TTFT p95 drops on a bimodal prompt mix.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.kernels.flash_decode import kernel as fdk, ref as fdr
from repro.models import transformer
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod

SERVE_ARCHS = (
    "gpt2-124m", "qwen3-1.7b", "mamba2-370m", "deepseek-v2-lite-16b",
    "deepseek-moe-16b", "jamba-1.5-large-398b",
)

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = configs.get_smoke_config(arch)
        _MODELS[arch] = (cfg, steps_mod.init_model(jax.random.PRNGKey(0), cfg))
    return _MODELS[arch]


# ---------------------------------------------------------------------------
# kernel: flash_prefill_paged vs the dense paged oracle
# ---------------------------------------------------------------------------


def _prefill_setup(B, C, KV, D, bs, nb, seed=0):
    """Random pools + shuffled block tables + a chunk at a ragged offset."""
    n_blocks = 1 + B * nb
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, KV, D), jnp.float32)
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, KV, D), jnp.float32)
    perm = np.random.default_rng(seed).permutation(np.arange(1, n_blocks))
    bt = jnp.asarray(perm[: B * nb].reshape(B, nb).astype(np.int32))
    k_new = jax.random.normal(ks[2], (B, C, KV, D), jnp.float32)
    v_new = jax.random.normal(ks[3], (B, C, KV, D), jnp.float32)
    # ragged, unaligned starts; the chunk must fit inside the slot view
    starts = np.random.default_rng(seed + 1).integers(0, nb * bs - C + 1, B)
    q_start = jnp.asarray(starts.astype(np.int32))
    return k_pool, v_pool, bt, k_new, v_new, q_start, ks[4]


FP_CASES = [
    # B, C, KV, G, D, bs, nb, block_c, block_s
    (1, 8, 1, 1, 16, 8, 4, 8, 0),
    (2, 8, 2, 2, 16, 8, 6, 4, 8),
    (2, 16, 2, 3, 32, 16, 3, 8, 8),
    (3, 4, 1, 2, 16, 4, 8, 2, 4),
]


@pytest.mark.parametrize("B,C,KV,G,D,bs,nb,bc,bks", FP_CASES)
def test_flash_prefill_paged_matches_ref(B, C, KV, G, D, bs, nb, bc, bks):
    k_pool, v_pool, bt, k_new, v_new, q_start, kq = _prefill_setup(
        B, C, KV, D, bs, nb)
    q = jax.random.normal(kq, (B, C, KV, G, D), jnp.float32)
    q_len = jax.random.randint(jax.random.PRNGKey(9), (B,), 1, C + 1)
    out, kp2, vp2 = fdk.flash_prefill_paged(
        q, k_new, v_new, k_pool, v_pool, bt, q_start, q_len,
        block_c=bc, block_s=bks)
    ref, kr2, vr2 = fdr.prefill_paged_ref(
        q, k_new, v_new, k_pool, v_pool, bt, q_start, q_len)
    # output rows at or past q_len are undefined by contract
    for b in range(B):
        n = int(q_len[b])
        np.testing.assert_allclose(
            np.asarray(out)[b, :n], np.asarray(ref)[b, :n],
            rtol=3e-5, atol=3e-5, err_msg=f"slot {b}")
    # committed pools must match bit-for-bit through the block tables
    np.testing.assert_array_equal(np.asarray(kp2[bt]), np.asarray(kr2[bt]))
    np.testing.assert_array_equal(np.asarray(vp2[bt]), np.asarray(vr2[bt]))


def test_flash_prefill_paged_full_chunk_default():
    """q_len=None commits the whole chunk (the common non-ragged call)."""
    B, C, KV, G, D, bs, nb = 2, 8, 2, 2, 16, 8, 4
    k_pool, v_pool, bt, k_new, v_new, q_start, kq = _prefill_setup(
        B, C, KV, D, bs, nb, seed=3)
    q = jax.random.normal(kq, (B, C, KV, G, D), jnp.float32)
    out1, kp1, vp1 = fdk.flash_prefill_paged(
        q, k_new, v_new, k_pool, v_pool, bt, q_start, block_c=4)
    full = jnp.full((B,), C, jnp.int32)
    out2, kp2, vp2 = fdk.flash_prefill_paged(
        q, k_new, v_new, k_pool, v_pool, bt, q_start, full, block_c=4)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(kp1), np.asarray(kp2))
    np.testing.assert_array_equal(np.asarray(vp1), np.asarray(vp2))


def test_flash_prefill_paged_tile_invariance():
    """block_c / block_s choose tiling, not math: outputs agree across
    tile shapes (the tuning space's correctness precondition)."""
    B, C, KV, G, D, bs, nb = 2, 16, 2, 2, 16, 8, 4
    k_pool, v_pool, bt, k_new, v_new, q_start, kq = _prefill_setup(
        B, C, KV, D, bs, nb, seed=4)
    q = jax.random.normal(kq, (B, C, KV, G, D), jnp.float32)
    q_len = jnp.asarray([11, 16], jnp.int32)
    outs = []
    for bc, bks in ((16, 0), (8, 8), (4, 4), (2, 8)):
        out, kp, vp = fdk.flash_prefill_paged(
            q, k_new, v_new, k_pool, v_pool, bt, q_start, q_len,
            block_c=bc, block_s=bks)
        outs.append((out, kp, vp))
    base_out, base_kp, base_vp = outs[0]
    for out, kp, vp in outs[1:]:
        for b in range(B):
            n = int(q_len[b])
            np.testing.assert_allclose(
                np.asarray(out)[b, :n], np.asarray(base_out)[b, :n],
                rtol=3e-5, atol=3e-5)
        # the commit path is tile-independent bit-for-bit
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(base_kp))
        np.testing.assert_array_equal(np.asarray(vp), np.asarray(base_vp))


def test_flash_prefill_paged_stale_blocks_are_inert():
    """Garbage at positions past the chunk frontier (recycled blocks, a
    previous tenant's tokens) cannot leak into any committed row's
    output — the causal frontier predication at chunk granularity."""
    B, C, KV, G, D, bs, nb = 2, 8, 2, 2, 16, 4, 6
    k_pool, v_pool, bt, k_new, v_new, q_start, kq = _prefill_setup(
        B, C, KV, D, bs, nb, seed=5)
    q = jax.random.normal(kq, (B, C, KV, G, D), jnp.float32)
    q_len = jnp.asarray([5, 8], jnp.int32)
    out1, _, _ = fdk.flash_prefill_paged(
        q, k_new, v_new, k_pool, v_pool, bt, q_start, q_len, block_c=4)
    # poison every pool row at a logical position >= q_start + q_len
    kp, vp = np.asarray(k_pool).copy(), np.asarray(v_pool).copy()
    for b in range(B):
        frontier = int(q_start[b]) + int(q_len[b])
        for j in range(nb):
            for o in range(bs):
                if j * bs + o >= frontier:
                    kp[int(bt[b, j]), o] = 99.0
                    vp[int(bt[b, j]), o] = -99.0
    out2, _, _ = fdk.flash_prefill_paged(
        q, k_new, v_new, jnp.asarray(kp), jnp.asarray(vp), bt, q_start,
        q_len, block_c=4)
    for b in range(B):
        n = int(q_len[b])
        np.testing.assert_allclose(
            np.asarray(out1)[b, :n], np.asarray(out2)[b, :n],
            rtol=1e-6, atol=1e-6)


def test_flash_prefill_paged_preserves_foreign_blocks():
    """Pool blocks belonging to OTHER slots (absent from this call's block
    tables) keep their bytes — the load-bearing invariant that lets the
    engine prefill one slot while its neighbors' caches stay live."""
    B, C, KV, G, D, bs, nb = 1, 8, 2, 2, 16, 8, 2
    n_blocks = 1 + 6  # more blocks than the single slot references
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    k_pool = jax.random.normal(ks[0], (n_blocks, bs, KV, D), jnp.float32)
    v_pool = jax.random.normal(ks[1], (n_blocks, bs, KV, D), jnp.float32)
    bt = jnp.asarray([[2, 5]], jnp.int32)  # blocks 1, 3, 4, 6 are foreign
    k_new = jax.random.normal(ks[2], (B, C, KV, D), jnp.float32)
    v_new = jax.random.normal(ks[3], (B, C, KV, D), jnp.float32)
    q = jax.random.normal(ks[4], (B, C, KV, G, D), jnp.float32)
    q_start = jnp.asarray([4], jnp.int32)
    _, kp2, vp2 = fdk.flash_prefill_paged(
        q, k_new, v_new, k_pool, v_pool, bt, q_start, block_c=4)
    for blk in (0, 1, 3, 4, 6):
        np.testing.assert_array_equal(
            np.asarray(kp2)[blk], np.asarray(k_pool)[blk], err_msg=f"k {blk}")
        np.testing.assert_array_equal(
            np.asarray(vp2)[blk], np.asarray(v_pool)[blk], err_msg=f"v {blk}")


def test_flash_prefill_registry_op_matches_ref():
    """The registry-managed op surface serves the same math as the oracle
    (tuned-kwarg resolution included)."""
    from repro.kernels.flash_decode import ops

    B, C, KV, G, D, bs, nb = 2, 8, 2, 2, 16, 8, 4
    k_pool, v_pool, bt, k_new, v_new, q_start, kq = _prefill_setup(
        B, C, KV, D, bs, nb, seed=7)
    q = jax.random.normal(kq, (B, C, KV, G, D), jnp.float32)
    out, kp, vp = ops.flash_prefill.interpret(
        q, k_new, v_new, k_pool, v_pool, bt, q_start)
    ref, kr, vr = ops.flash_prefill.ref(
        q, k_new, v_new, k_pool, v_pool, bt, q_start)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(kp[bt]), np.asarray(kr[bt]))
    np.testing.assert_array_equal(np.asarray(vp[bt]), np.asarray(vr[bt]))


def test_prefill_flops_bytes_model():
    fb = fdr.prefill_flops_bytes(2, 8, 2, 2, 16, q_start=[16, 0])
    # live key-reads: q_start*C + C(C+1)/2 per slot
    live = (16 * 8 + 36) + (0 * 8 + 36)
    assert fb["flops"] == 4.0 * 2 * 2 * 16 * live
    assert fb["bytes"] == 2.0 * 2 * 16 * 2 * (live + 2 * 8)
    assert fb["ai"] > 0


# ---------------------------------------------------------------------------
# model: prefill_step_paged == a chain of single-token steps, bit for bit
# ---------------------------------------------------------------------------


def _assert_caches_bit_equal(c1, c2, msg=""):
    """Paged caches equal everywhere a request can read: every non-null
    pool block (block 0 is the garbage null block) and all dense state."""
    for slot, d1 in c1["blocks"].items():
        for k, leaf in d1.items():
            a, b = np.asarray(leaf), np.asarray(c2["blocks"][slot][k])
            if k in ("k", "v", "c", "k_rope"):
                np.testing.assert_array_equal(
                    a[:, 1:], b[:, 1:], err_msg=f"{msg}{slot}/{k}")
            else:
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{msg}{slot}/{k}")
    if "first_block" in c1:
        for k, leaf in c1["first_block"].items():
            np.testing.assert_array_equal(
                np.asarray(leaf)[1:], np.asarray(c2["first_block"][k])[1:],
                err_msg=f"{msg}first_block/{k}")


def _fresh_paged(cfg, B, max_len, bs):
    cache = transformer.init_paged_cache(cfg, B, max_len, bs)
    nb = max_len // bs
    bt = np.arange(1, 1 + B * nb, dtype=np.int32).reshape(B, nb)
    return cache, jnp.asarray(bt)


def test_decode_step_is_the_chunk1_prefill_cell():
    """decode_step_paged must be bitwise the C=1 cell of prefill_step_paged
    (the refactor that makes chunked serving golden by construction)."""
    cfg, params = _model("gpt2-124m")
    B, max_len, bs = 2, 32, 8
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)).astype(np.int32))
    pos = jnp.zeros((B,), jnp.int32)
    cache_d, bt = _fresh_paged(cfg, B, max_len, bs)
    cache_p, _ = _fresh_paged(cfg, B, max_len, bs)
    logits_d, cache_d = transformer.decode_step_paged(
        params, cfg, tokens, cache_d, pos, bt, block_size=bs)
    logits_p, cache_p = transformer.prefill_step_paged(
        params, cfg, tokens, cache_p, pos, bt, jnp.ones((B,), jnp.int32),
        block_size=bs)
    np.testing.assert_array_equal(np.asarray(logits_d),
                                  np.asarray(logits_p))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cache_d, cache_p)


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_prefill_chunk_bit_equals_token_chain(arch):
    """One C=7 chunked call == seven C=1 calls with the same per-slot
    active schedule: bit-identical last-prompt-token logits AND
    bit-identical cache bytes (pools, SSM state) on every architecture."""
    cfg, params = _model(arch)
    B, max_len, bs, C = 2, 32, 8, 7
    plen = np.array([7, 4], np.int32)  # ragged: slot 1 goes inactive early
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (B, C)).astype(np.int32)
    pos0 = jnp.zeros((B,), jnp.int32)

    cache_c, bt = _fresh_paged(cfg, B, max_len, bs)
    logits_c, cache_c = transformer.prefill_step_paged(
        params, cfg, jnp.asarray(prompts), cache_c, pos0, bt,
        jnp.asarray(plen), block_size=bs)

    cache_t, _ = _fresh_paged(cfg, B, max_len, bs)
    last = {}
    for c in range(C):
        lens = (c < plen).astype(np.int32)  # (B,) active mask: 1 or 0
        logits_t, cache_t = transformer.prefill_step_paged(
            params, cfg, jnp.asarray(prompts[:, c:c + 1]), cache_t,
            pos0 + c, bt, jnp.asarray(lens), block_size=bs)
        for b in range(B):
            if c == plen[b] - 1:
                last[b] = np.asarray(logits_t)[b, 0]

    for b in range(B):
        np.testing.assert_array_equal(
            np.asarray(logits_c)[b, plen[b] - 1], last[b],
            err_msg=f"{arch} slot {b} logits")
    _assert_caches_bit_equal(cache_c, cache_t, msg=f"{arch} ")


# ---------------------------------------------------------------------------
# engine: chunked serving is golden vs token-by-token
# ---------------------------------------------------------------------------


def _run_engine(arch, prompts, max_new, *, chunk=1, budget=None,
                max_batch=2, max_len=64, block_size=8, eos=()):
    cfg, params = _model(arch)
    eng = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                      scheduler="continuous", block_size=block_size,
                      prefill_chunk=chunk, prefill_budget=budget)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new,
                           eos_id=eos[uid] if eos else -1))
    eng.run_until_drained()
    return eng


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_engine_chunked_matches_token_by_token(arch):
    """Across every serve architecture: identical streams, strictly fewer
    fused steps under chunked prefill on ragged prompts."""
    cfg, _ = _model(arch)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)
               for n in (19, 4, 11, 26)]
    base = _run_engine(arch, prompts, 4)
    chunked = _run_engine(arch, prompts, 4, chunk=8, budget=8)
    for uid in range(len(prompts)):
        assert chunked.completed[uid].generated == \
            base.completed[uid].generated, f"{arch} req {uid}"
    assert chunked.steps < base.steps, (arch, chunked.steps, base.steps)


def test_engine_chunk_sweep_identical_streams():
    """Chunk widths 1 / ragged non-divisor / full-prompt: byte-identical
    streams, fused steps non-increasing in chunk width (strictly fewer
    than token-by-token for every C > 1)."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (13, 5, 29, 8)]
    runs = {c: _run_engine("gpt2-124m", prompts, 5, chunk=c)
            for c in (1, 3, 7, 32)}
    base = runs[1]
    steps = [runs[c].steps for c in (1, 3, 7, 32)]
    for c, eng in runs.items():
        for uid in range(len(prompts)):
            assert eng.completed[uid].generated == \
                base.completed[uid].generated, (c, uid)
        if c > 1:
            assert eng.steps < base.steps, (c, eng.steps, base.steps)
    assert steps == sorted(steps, reverse=True), steps


def test_engine_chunked_ttft_win_on_bimodal_mix():
    """The disaggregation headline on a bimodal prompt mix (short decode
    traffic + long prompts): deterministic step-clock TTFT p95 strictly
    drops, streams stay byte-identical, EOS still honored."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(13)
    lens = (48, 4, 48, 4, 4, 48)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in lens]
    base = _run_engine("gpt2-124m", prompts, 4)
    chunked = _run_engine("gpt2-124m", prompts, 4, chunk=16, budget=16)
    for uid in range(len(prompts)):
        assert chunked.completed[uid].generated == \
            base.completed[uid].generated, uid
    bs_, cs_ = base.stats(), chunked.stats()
    assert cs_["ttft_p95_steps"] < bs_["ttft_p95_steps"], (cs_, bs_)
    assert cs_["ttft_p50_steps"] < bs_["ttft_p50_steps"], (cs_, bs_)
    assert chunked.steps < base.steps
    # the stats schema the ledger ingests carries the prefill config
    assert cs_["prefill_chunk"] == 16
    assert bs_["prefill_chunk"] == 1


def test_engine_chunked_respects_eos():
    """Early EOS fires on the same token under chunked prefill (the argmax
    only ever runs on a slot's frontier row)."""
    cfg, _ = _model("gpt2-124m")
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (9, 17)]
    probe = _run_engine("gpt2-124m", [prompts[0]], 1, max_batch=1)
    eos0 = probe.completed[0].generated[0]
    base = _run_engine("gpt2-124m", prompts, 6, eos=(eos0, -1))
    chunked = _run_engine("gpt2-124m", prompts, 6, chunk=8, eos=(eos0, -1))
    assert chunked.completed[0].generated == [eos0]
    for uid in range(2):
        assert chunked.completed[uid].generated == \
            base.completed[uid].generated
