"""Chunked online-softmax attention vs a naive reference — forward and VJP.

The chunked path is the memory-lean schedule a Pallas splash kernel executes;
it must be numerically identical (up to fp accumulation) to materialized
softmax(QK^T)V for every (GQA grouping, causality, ragged length, chunking).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention

NEG_INF = -1e30


def naive_attention(q, k, v, *, causal, q_offset=0, kv_valid_len=None):
    """q: (B,Sq,KV,G,D); k/v: (B,Sk,KV,D)."""
    B, Sq, KV, G, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if kv_valid_len is not None:
        mask = mask & (k_pos[None, :] < kv_valid_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def _mk(key, B, Sq, Sk, KV, G, D, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, KV, G, D), dtype)
    k = jax.random.normal(k2, (B, Sk, KV, D), dtype)
    v = jax.random.normal(k3, (B, Sk, KV, D), dtype)
    return q, k, v


CASES = [
    # B, Sq, Sk, KV, G, D, causal, q_chunk, k_chunk
    (1, 16, 16, 1, 1, 8, True, 16, 16),
    (2, 32, 32, 2, 2, 16, True, 8, 8),
    (1, 17, 17, 1, 4, 8, True, 8, 4),     # ragged: not a chunk multiple
    (1, 33, 64, 2, 1, 8, False, 16, 16),  # cross-attention (Sq != Sk)
    (2, 8, 40, 1, 2, 16, False, 8, 8),
    (1, 64, 64, 4, 1, 8, True, 64, 64),   # single chunk (no tiling effects)
]


@pytest.mark.parametrize("B,Sq,Sk,KV,G,D,causal,qc,kc", CASES)
def test_forward_matches_naive(B, Sq, Sk, KV, G, D, causal, qc, kc):
    q, k, v = _mk(jax.random.PRNGKey(0), B, Sq, Sk, KV, G, D)
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    ref = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    q, k, v = _mk(jax.random.PRNGKey(1), 2, 24, 24, 2, 2, 16, dtype)
    out = flash_attention(q, k, v, causal=True, q_chunk=8, k_chunk=8)
    ref = naive_attention(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )
    assert out.dtype == dtype


def test_chunking_is_invisible():
    """Same inputs, different tilings -> same output (online softmax exact)."""
    q, k, v = _mk(jax.random.PRNGKey(2), 1, 48, 48, 2, 2, 8)
    outs = [
        flash_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
        for qc, kc in [(48, 48), (16, 8), (8, 16), (12, 48)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), rtol=1e-5, atol=1e-5)


def test_q_offset_decode_window():
    """q_offset shifts causal masking for chunked prefill continuation."""
    q, k, v = _mk(jax.random.PRNGKey(3), 1, 4, 32, 1, 1, 8)
    out = flash_attention(q, k, v, causal=True, q_offset=28, q_chunk=4, k_chunk=8)
    ref = naive_attention(q, k, v, causal=True, q_offset=28)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kv_valid_len_masks_tail():
    q, k, v = _mk(jax.random.PRNGKey(4), 1, 8, 32, 1, 1, 8)
    valid = jnp.asarray(20)
    out = flash_attention(q, k, v, causal=False, kv_valid_len=valid, k_chunk=8)
    ref = naive_attention(q, k, v, causal=False, kv_valid_len=20)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    # poisoning the masked tail must not change the output
    k_poison = k.at[:, 20:].set(100.0)
    v_poison = v.at[:, 20:].set(-100.0)
    out2 = flash_attention(q, k_poison, v_poison, causal=False, kv_valid_len=valid, k_chunk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("causal", [True, False])
def test_gradients_match_naive(causal):
    """Custom FA2-style VJP vs autodiff through the naive reference."""
    q, k, v = _mk(jax.random.PRNGKey(5), 1, 24, 24, 2, 2, 8)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, q_chunk=8, k_chunk=8)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_naive(q, k, v):
        o = naive_attention(q, k, v, causal=causal)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4,
            err_msg=f"d{name} mismatch",
        )


def test_vjp_saves_only_qkv_out_lse():
    """The residual memory contract: no O(S^2) tensors saved by the VJP."""
    q, k, v = _mk(jax.random.PRNGKey(6), 1, 32, 32, 1, 1, 8)
    f = functools.partial(flash_attention, causal=True, q_chunk=8, k_chunk=8)
    _, vjp_fn = jax.vjp(f, q, k, v)
    residual_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(vjp_fn)
        if hasattr(x, "shape")
    )
    S, D = 32, 8
    # q+k+v+out ~ 4*S*D fp32 + lse S; generous 3x slack, far below S^2 tiles
    assert residual_bytes < 3 * (5 * S * D * 4), residual_bytes
