"""Batched LM serving: request queue -> slot-level continuous batching.

Shows the serving shape the decode_* dry-run cells model: one jitted
fused decode step advances the whole batch one token per call.  Under the
default continuous scheduler every slot carries its own position in a
paged KV cache and finished slots refill from the queue mid-flight; the
legacy lockstep scheduler (``scheduler="wave"``) runs the same trace for
contrast — identical greedy tokens, more fused steps, lower slot
utilization (Eq. 1's predication lesson at the serving layer; see
docs/SERVING.md).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

import repro.configs as configs
from repro.serve.engine import Request, ServeEngine
from repro.train import steps as steps_mod


def main():
    cfg = configs.get_smoke_config("qwen3-1.7b")
    params = steps_mod.init_model(jax.random.PRNGKey(0), cfg)

    engines = {}
    for scheduler in ("wave", "continuous"):
        engine = ServeEngine(cfg, params, max_batch=4, max_len=96,
                             scheduler=scheduler, block_size=16)
        rng = np.random.default_rng(0)
        n_requests = 10
        for uid in range(n_requests):
            plen = int(rng.integers(3, 24))
            engine.submit(Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab, size=plen).astype(np.int32),
                max_new_tokens=int(rng.integers(4, 12)),
            ))
        t0 = time.time()
        done = engine.run_until_drained()
        dt = time.time() - t0
        new_tokens = sum(len(r.generated) for r in done.values())
        print(f"[{scheduler}] served {len(done)} requests / {new_tokens} "
              f"new tokens in {engine.steps} fused steps, {dt:.2f}s "
              f"({new_tokens/dt:.1f} tok/s, slot utilization "
              f"{engine.slot_utilization:.3f})")
        assert len(done) == n_requests
        engines[scheduler] = engine

    wave, cont = engines["wave"], engines["continuous"]
    for uid in sorted(cont.completed):
        r = cont.completed[uid]
        assert r.generated == wave.completed[uid].generated  # golden tokens
        print(f"  req {uid:2d}: prompt len {len(r.prompt):2d} -> "
              f"{len(r.generated):2d} tokens: {r.generated[:8]}"
              f"{'...' if len(r.generated) > 8 else ''}")
    assert cont.steps <= wave.steps
    print(f"continuous spent {wave.steps - cont.steps} fewer fused steps "
          f"than lockstep on the same trace")


if __name__ == "__main__":
    main()
